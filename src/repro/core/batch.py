"""Batched SmartFill planning — solve many scheduling instances at once.

The device-resident solver core (``core/smartfill.py``) takes a traced
active-job count, so a whole fleet of independent (x, w, B) instances can
be planned in **one** ``jax.vmap``'d call: thousands of tenants, one
device program, no Python loop.  This is the planning throughput a
multi-tenant controller needs (cf. the multi-class workloads of Berg et
al., arXiv:2404.00346) and what closed-form baselines like heSRPT get
for free.

Padding / masking convention (matches ``solve_cap``'s ``active`` mask):

  * all instances are padded to a common width M (the max job count);
  * ``active`` is a **prefix** mask per instance — real jobs occupy
    slots 0..m−1, padding occupies m..M−1;
  * padded slots carry x = 0, w = 0 (enforced internally: inactive
    entries are zeroed before the solve);
  * within its active prefix each instance must be sorted the SmartFill
    way: sizes non-increasing, weights non-decreasing;
  * ``B`` may be a scalar (shared server) or an (N,) vector (one budget
    per instance).

Speedup batching (one convention, two axes):

  * leaves with leading dimension N are per-instance (vmapped along
    their instance — e.g. the (K,) family parameters from
    ``core/workloads.py``);
  * leaves with a dimension *beyond* that are per-job (paper §7):
    ``(N, M)`` leaves give every job of every instance its own function
    — inside the vmap each lane sees ``(M,)`` job-indexed leaves and
    the solver takes the heterogeneous λ-bisection path;
  * ``smartfill_hetero_batched`` adds the per-instance completion-order
    search on top (rows must otherwise already be in completion order).

Padded outputs are exact zeros: theta rows/cols, c, a, durations and T
of padded slots are 0, and J only sums active jobs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .smartfill import (SmartFillSchedule, _fast_ok, _solve,
                        _validate_instance, normalized_order)
from .speedup import Speedup, collapse_homogeneous

__all__ = [
    "BatchedSmartFillSchedule",
    "batch_axes",
    "check_axes_unambiguous",
    "current_allocations_from",
    "hetero_order_batch",
    "smartfill_batched",
    "smartfill_hetero_batched",
    "smartfill_allocations_batched",
    "validate_padded_instances",
]


def batch_axes(tree, K: int):
    """vmap in_axes for ``tree``: leaves with leading dim K map on 0.

    The same convention as ``simulate_ensemble``'s speedup/policy
    batching — any pytree leaf with leading dimension K is treated as
    per-instance data; everything else is shared.
    """
    return jax.tree_util.tree_map(
        lambda l: 0 if (hasattr(l, "ndim") and getattr(l, "ndim", 0) >= 1
                        and l.shape[0] == K) else None, tree)


def check_axes_unambiguous(tree, K: int, M: int, what: str) -> None:
    """With K == M a 1-D (K,) leaf could equally be per-job data; refuse
    to guess (a wrong guess silently corrupts every instance)."""
    if K != M:
        return
    for leaf in jax.tree_util.tree_leaves(tree):
        if getattr(leaf, "ndim", 0) == 1 and leaf.shape[0] == K:
            raise ValueError(
                f"{what} has a 1-D leaf of length {K} but K == M — "
                "per-instance (K,) leaves cannot be told apart from "
                "per-job (M,) leaves; reshape per-instance leaves to "
                "(K, 1) (they broadcast) or pick K ≠ M")


def validate_padded_instances(X, W, m) -> None:
    """Host-check the sorting convention on each padded instance.

    Raises ValueError naming the first offending instance whose active
    prefix (slots 0..m−1) is not sizes-non-increasing with weights
    non-decreasing.  Shared by ``smartfill_batched(validate=True)`` and
    the serving tier's admission controller.
    """
    ms = np.asarray(m)
    xs, ws = np.asarray(X), np.asarray(W)
    for n in range(xs.shape[0]):
        k = int(ms[n])
        if k == 0:
            continue
        try:
            _validate_instance(xs[n, :k], ws[n, :k])
        except ValueError as e:
            raise ValueError(f"instance {n}: {e}") from e


@dataclasses.dataclass(frozen=True)
class BatchedSmartFillSchedule:
    """Stacked SmartFill outputs for N padded instances.

    theta: (N, M, M); c/a/durations/T: (N, M); J/J_linear: (N,);
    active: (N, M) prefix masks; m: (N,) active-job counts.
    All fields stay on device — no host sync until the caller reads them.
    """

    theta: jnp.ndarray
    c: jnp.ndarray
    a: jnp.ndarray
    durations: jnp.ndarray
    T: jnp.ndarray
    J: jnp.ndarray
    J_linear: jnp.ndarray
    active: jnp.ndarray
    m: jnp.ndarray

    def __len__(self) -> int:
        return int(self.theta.shape[0])

    def instance(self, i: int) -> SmartFillSchedule:
        """Materialize instance ``i`` as a plain SmartFillSchedule."""
        return SmartFillSchedule(
            theta=self.theta[i], c=self.c[i], a=self.a[i],
            durations=self.durations[i], T=self.T[i],
            J=float(self.J[i]), J_linear=float(self.J_linear[i]),
        )


def _prepare(X, W, active):
    X = jnp.asarray(X, dtype=jnp.result_type(float))
    W = jnp.asarray(W, dtype=X.dtype)
    if X.ndim != 2 or W.shape != X.shape:
        raise ValueError("X and W must both be (N, M)")
    if active is None:
        active = X > 0
    active = jnp.asarray(active, bool)
    if active.shape != X.shape:
        raise ValueError("active mask must be (N, M)")
    m = jnp.sum(active, axis=1)
    # The solver consumes only the *count* m with prefix semantics, so a
    # non-prefix mask (e.g. an interior zero-size slot from an unsorted
    # row) would silently drop real jobs.  Reject it whenever the mask is
    # concrete; under tracing the caller owns the convention.
    try:
        act = np.asarray(active)
    except jax.errors.TracerArrayConversionError:
        act = None
    if act is not None:
        prefix = np.arange(act.shape[1])[None, :] < act.sum(axis=1)[:, None]
        if not np.array_equal(act, prefix):
            bad = int(np.flatnonzero((act != prefix).any(axis=1))[0])
            raise ValueError(
                f"active must be a prefix mask per instance (real jobs "
                f"first, padding after); instance {bad} has interior gaps")
    Xm = jnp.where(active, X, 0.0)
    Wm = jnp.where(active, W, 0.0)
    return Xm, Wm, active, m


def smartfill_batched(
    sp: Speedup,
    X,
    W,
    B=None,
    active=None,
    coarse: int = 32,
    descent_iters: int = 40,
    cap_iters: int = 64,
    fast_path: bool | None = None,
    validate: bool = False,
    stol_rel: float | None = None,
) -> BatchedSmartFillSchedule:
    """SmartFill over N padded instances in a single vmap'd device call.

    Args:
      sp: shared speedup function (not vmapped — one server model).
      X: (N, M) padded job sizes.
      W: (N, M) padded weights.
      B: scalar or (N,) budgets; defaults to sp.B.
      active: optional (N, M) prefix masks; defaults to ``X > 0``.
      fast_path: as in ``smartfill`` — None auto-detects pure power.
      stol_rel: μ* descent exit tolerance override (see ``smartfill``);
        None keeps the size-dependent default.  The class-aggregated
        planners tighten this (J at clamped-duration kinks is linearly
        sensitive to μ*, and at C ≲ 64 rows the extra iterations are
        nearly free).
      validate: host-side check of the per-instance sorting convention
        (syncs; off by default to keep the call device-resident).  The
        prefix-mask property is always enforced when the mask is
        concrete, since a non-prefix mask would silently drop jobs.

    Returns a BatchedSmartFillSchedule.
    """
    Xm, Wm, active, m = _prepare(X, W, active)
    N = Xm.shape[0]
    if B is None:
        B = sp.B
    Bv = jnp.broadcast_to(jnp.asarray(B, Xm.dtype), (N,))

    if validate:
        validate_padded_instances(Xm, Wm, m)

    # constant job-indexed leaves collapse to the shared fast paths;
    # the closed-form μ* additionally requires no per-job leaves inside
    # the vmap (a leading N axis of per-instance scalars is fine)
    sp = collapse_homogeneous(sp)
    fast = _fast_ok(sp, N) and fast_path is not False
    # Per-instance speedup parameters: any pytree leaf of sp with leading
    # dimension N (e.g. the (K,)-leaved RegularSpeedup batches from
    # core/workloads.py) is vmapped alongside its instance, exactly as in
    # simulate_ensemble; (N, M) leaves are per-instance *per-job* (§7).
    # Scalar leaves stay shared.
    check_axes_unambiguous(sp, N, Xm.shape[1], "sp")
    sp_axes = batch_axes(sp, N)
    theta, c, a, d, T, J, J_lin, _, _ = jax.vmap(
        lambda spv, x, w, b, mm: _solve(spv, x, w, b, mm,
                                        coarse, descent_iters, cap_iters,
                                        fast, stol_rel=stol_rel),
        in_axes=(sp_axes, 0, 0, 0, 0),
    )(sp, Xm, Wm, Bv, m)
    return BatchedSmartFillSchedule(
        theta=theta, c=c, a=a, durations=d, T=T,
        J=J, J_linear=J_lin, active=active, m=m,
    )


def smartfill_hetero_batched(
    sp: Speedup,
    X,
    W,
    B=None,
    active=None,
    **kwargs,
):
    """Heterogeneous batched planning: per-instance order search + solve.

    The fleet front door for per-job speedups (paper §7): for each
    padded instance the completion order is chosen by
    SJF-by-normalized-size under each job's own s_i (the
    ``normalized_order`` heuristic — ties by weight), rows and per-job
    ``(N, M)`` speedup leaves are permuted accordingly, and the whole
    batch is solved in one ``smartfill_batched`` call.

    Unlike ``smartfill_batched`` the rows of X/W need **not** arrive
    sorted — the order is part of the decision.  Padding stays a prefix:
    only the active prefix of each row is permuted.  Inputs must be
    concrete (the order is computed host-side); adjacent-exchange
    refinement is the single-instance planner's job
    (``smartfill_hetero``), not the fleet path's.

    Returns ``(orders, BatchedSmartFillSchedule)`` where ``orders[n][r]``
    is the original column of instance n occupying schedule row r.
    """
    Xm, Wm, active, m = _prepare(X, W, active)
    N, M = Xm.shape
    if B is None:
        B = sp.B
    sp = collapse_homogeneous(sp)
    check_axes_unambiguous(sp, N, M, "sp")
    orders, sp_p, Xp, Wp = hetero_order_batch(sp, Xm, Wm, m, B)
    sched = smartfill_batched(sp_p, Xp, Wp, B=B, active=active, **kwargs)
    return orders, sched


def hetero_order_batch(sp, Xm, Wm, m, B):
    """Per-instance §7 order heuristic + batch permutation (host-side).

    The shared prep of ``smartfill_hetero_batched`` and the fleet's
    class-aggregate planner: for each padded instance compute the
    SJF-by-normalized-size order over its live prefix, then permute
    rows and per-job speedup leaves accordingly.  ``Xm``/``Wm``/``m``
    follow ``_prepare``'s conventions (prefix-live padded rows).
    Returns ``(orders, sp_p, Xp, Wp)`` ready for any batched solver.
    """
    N, M = Xm.shape
    Xh = np.asarray(Xm)
    Wh = np.asarray(Wm)
    ms = np.asarray(m)
    Bv = np.broadcast_to(np.asarray(B, dtype=np.float64), (N,))

    leaves, treedef = jax.tree_util.tree_flatten(sp)
    arrs = [np.asarray(l) for l in leaves]

    def instance_speedup(n, mk):
        """Instance n's speedup with job leaves cut to its live prefix."""
        cut = []
        for a in arrs:
            v = a[n] if (a.ndim >= 1 and a.shape[0] == N) else a
            if getattr(v, "ndim", 0) >= 1:
                v = v[:mk]          # job-indexed: prefix of live jobs
            cut.append(v)
        return jax.tree_util.tree_unflatten(treedef, cut)

    orders = np.tile(np.arange(M), (N, 1))
    for n in range(N):
        mk = int(ms[n])
        if mk == 0:
            continue
        orders[n, :mk] = normalized_order(
            instance_speedup(n, mk), Xh[n, :mk], Wh[n, :mk], float(Bv[n]))

    gather = jnp.asarray(orders)
    Xp = jnp.take_along_axis(Xm, gather, axis=1)
    Wp = jnp.take_along_axis(Wm, gather, axis=1)

    def permute_leaf(l):
        arr = jnp.asarray(l)
        if arr.ndim >= 2 and arr.shape[0] == N and arr.shape[1] == M:
            return jnp.take_along_axis(arr, gather, axis=1)
        if arr.ndim == 1 and arr.shape[0] == M:
            return arr[gather]      # shared per-job → per-instance copies
        return l

    sp_p = jax.tree_util.tree_map(permute_leaf, sp)
    return orders, sp_p, Xp, Wp


def smartfill_allocations_batched(
    sp: Speedup,
    REM,
    W,
    B=None,
    active=None,
    **kwargs,
) -> jnp.ndarray:
    """Instantaneous optimal allocations for N fleets in one device call.

    The batched analogue of ``smartfill_allocations``: for each instance
    the current allocation is column m−1 of its SmartFill plan (the
    earliest phase, with all m active jobs live).  Returns (N, M)
    allocations; padded slots are 0.
    """
    return current_allocations_from(
        smartfill_batched(sp, REM, W, B=B, active=active, **kwargs))


def current_allocations_from(sched: BatchedSmartFillSchedule) -> jnp.ndarray:
    """Current-instant allocations of an already-solved batched plan.

    Column m−1 of each instance's schedule (the earliest phase, all m
    active jobs live) — shared by ``smartfill_allocations_batched`` and
    the sharded fleet planner's consumers, which hold a
    ``BatchedSmartFillSchedule`` from ``plan_sharded`` instead.
    """
    M = sched.theta.shape[-1]
    col = jnp.clip(sched.m - 1, 0, M - 1)
    th = jnp.take_along_axis(sched.theta, col[:, None, None], axis=2)[..., 0]
    return jnp.where(sched.active & (sched.m > 0)[:, None], th, 0.0)
