"""Core algorithms from the paper: speedup families, GWF, SmartFill,
heSRPT baseline, CDR verification, and the event-driven simulator."""
from .speedup import (  # noqa: F401
    GenericSpeedup,
    RegularSpeedup,
    Speedup,
    StackedSpeedup,
    broadcast_speedup,
    collapse_homogeneous,
    from_roofline,
    is_per_job,
    log_speedup,
    neg_power,
    power,
    saturating,
    shifted_power,
    stack_speedups,
    take_job,
)
from .gwf import (  # noqa: F401
    HeteroPrep,
    hetero_approx,
    hetero_breakpoints_init,
    hetero_breakpoints_insert,
    hetero_prepare,
    hetero_solve,
    solve_cap,
    solve_cap_batched,
    solve_cap_generic,
    solve_cap_hetero,
    solve_cap_hetero_sorted,
    solve_cap_regular,
    solve_cap_regular_reference,
)
from .smartfill import (  # noqa: F401
    HeteroSmartFillSchedule,
    SmartFillSchedule,
    completion_times,
    normalized_order,
    objective,
    smartfill,
    smartfill_allocations,
    smartfill_hetero,
    smartfill_hetero_reference,
    smartfill_reference,
)
from .batch import (  # noqa: F401
    BatchedSmartFillSchedule,
    smartfill_allocations_batched,
    smartfill_batched,
    smartfill_hetero_batched,
)
from .classes import (  # noqa: F401
    ClassPlan,
    ClassState,
    aggregate_classes,
    class_speedup,
    compact_aggregate_batch,
    expand_classes,
    plan_classes,
    plan_classes_batched,
    plan_classes_reference,
)
from .hesrpt import fit_power, hesrpt_allocations, hesrpt_policy  # noqa: F401
from .cdr import cdr_violation, estimate_constants  # noqa: F401
from .simulator import (  # noqa: F401
    EnsembleResult,
    FluidClassResult,
    SimResult,
    n_events_for,
    schedule_policy,
    simulate_ensemble,
    simulate_fluid_classes,
    simulate_policy,
    simulate_policy_device,
    simulate_policy_reference,
    smartfill_sim_policy,
)
from .workloads import (  # noqa: F401
    FAMILIES,
    ClassWorkloadBatch,
    WorkloadBatch,
    sample_class_workloads,
    sample_workloads,
)
