"""heSRPT — the baseline policy of Berg, Vesilo & Harchol-Balter (2020).

heSRPT is the *optimal* policy when the speedup function is a pure power
law ``s(θ) = a θ^p`` (0 < p < 1).  Its allocations are scale-free — they
depend only on the weights, not the sizes (Theorem 3 in [2]): when the k
largest-remaining jobs 1..k are active (sizes non-increasing, weights
non-decreasing),

    θ_i / B = (W_i^{1/(1−p)} − W_{i−1}^{1/(1−p)}) / W_k^{1/(1−p)},
    W_i = Σ_{j ≤ i} w_j,  W_0 = 0.

Sanity limits: p → 1 gives pure SRPT (everything to the smallest job);
p → 0 gives allocation ∝ w_i.

For general concave s the paper's benchmark ("approximation-based
heSRPT") first fits s with ``ã θ^p̃`` and then runs the closed form under
the fitted exponent, re-planning at completion events while the *true* s
drives the dynamics.  ``fit_power`` reproduces the paper's fits
(0.79 θ^0.48 for log(1+θ); 0.26 θ^0.82 for √(4+θ)−2 on [0, 10]).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "hesrpt_allocations",
    "hesrpt_policy",
    "hesrpt_open_loop",
    "fit_power",
]


def hesrpt_allocations(w, p: float, B: float) -> np.ndarray:
    """Closed-form heSRPT shares for active jobs with weights ``w``.

    ``w`` must be aligned with jobs sorted by remaining size
    non-increasing (so w is non-decreasing).  Returns allocations summing
    to B.  Note the shares do not depend on ``a`` or the sizes.
    """
    w = np.asarray(w, dtype=np.float64)
    m = 1.0 / (1.0 - p)
    W = np.cumsum(w)
    Wm = np.concatenate([[0.0], W]) ** m
    return B * (Wm[1:] - Wm[:-1]) / Wm[-1]


def hesrpt_policy(p: float, B: float):
    """Policy closure for the event-driven simulator.

    policy(rem, w, active) → full-length allocation vector.  Active jobs
    are ranked by remaining size (desc; ties by weight asc) and receive
    the closed-form heSRPT shares.
    """

    def policy(rem, w, active):
        rem = np.asarray(rem, dtype=np.float64)
        w = np.asarray(w, dtype=np.float64)
        theta = np.zeros_like(rem)
        idx = np.flatnonzero(active)
        if idx.size == 0:
            return theta
        # sort: largest remaining first; stable tie-break by weight asc
        order = idx[np.lexsort((w[idx], -rem[idx]))]
        theta[order] = hesrpt_allocations(w[order], p, B)
        return theta

    return policy


def hesrpt_open_loop(sp_true, x, w, p: float, a: float, B: float,
                     rtol: float = 1e-12):
    """Open-loop approximation-based heSRPT (paper §6.2 benchmark).

    The schedule — phase allocations *and* phase boundaries — is computed
    once under the fitted model ``s̃(θ) = a θ^p`` and then executed over
    wall-clock time while the *true* speedup drives the dynamics.  When a
    job completes earlier than planned its bandwidth idles until the next
    planned phase boundary; a job still unfinished when the plan says it
    is done receives nothing until the plan's horizon, after which the
    leftovers are drained with event-driven heSRPT.

    This is the pessimistic reading of "apply heSRPT with an approximate
    s"; the event-driven reading is ``hesrpt_policy`` + simulate_policy.
    Together they bracket any reasonable heSRPT implementation.
    """
    x = np.asarray(x, dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    M = x.shape[0]

    # --- plan under the fitted model (jobs sorted: x non-increasing) ----
    alloc = np.zeros((M, M))            # alloc[i, j]: rate of job i, phase j
    for j in range(M):                  # phase j has jobs 0..j active
        alloc[: j + 1, j] = hesrpt_allocations(w[: j + 1], p, B)
    s_fit = lambda t: a * np.maximum(t, 0.0) ** p
    rate_fit = np.where(np.triu(np.ones((M, M))) > 0, s_fit(alloc), 0.0)
    # planned durations: x = R d (upper-triangular back-substitution)
    d_plan = np.zeros(M)
    for jj in range(M - 1, -1, -1):
        served = rate_fit[jj, jj + 1:] @ d_plan[jj + 1:]
        d_plan[jj] = max(x[jj] - served, 0.0) / max(rate_fit[jj, jj], 1e-300)

    # --- execute under the true speedup --------------------------------
    rem = x.copy()
    T = np.zeros(M)
    t = 0.0
    tol = rtol * max(1.0, float(x.max()))
    for j in range(M - 1, -1, -1):      # planned phases, earliest first
        seg = d_plan[j]
        theta = alloc[:, j]
        rates = np.array(sp_true.s(theta), dtype=np.float64)
        while seg > 0:
            active = rem > tol
            runnable = active & (rates > 0)
            if not runnable.any():
                break
            dts = rem[runnable] / rates[runnable]
            dt = min(float(dts.min()), seg)
            rem = np.maximum(rem - rates * dt * (rem > tol), 0.0)
            t += dt
            seg -= dt
            done = active & (rem <= tol)
            T[done] = t
            rem[done] = 0.0
    # --- drain leftovers (plan horizon exhausted) -----------------------
    if (rem > tol).any():
        from .simulator import simulate_policy

        left = rem > tol

        class _Shift:                   # simulate on the leftover subset
            pass

        idx = np.flatnonzero(left)
        sub = simulate_policy(sp_true, rem[idx], w[idx],
                              hesrpt_policy(p, B), B=B, rtol=rtol)
        T[idx] = t + sub.T
    return T, float(np.sum(w * T))


def fit_power(s_fn, B: float, n: int = 1024, theta_min: float = 1e-2,
              method: str = "linear"):
    """Least-squares fit  s(θ) ≈ a θ^p  on (0, B].

    ``method='linear'`` minimizes Σ (a θ^p − s(θ))² — this reproduces the
    paper's fits (Fig. 7: 0.79 θ^0.48 for log(1+θ); Fig. 9: 0.26 θ^0.82
    for √(4+θ)−2).  ``method='loglog'`` is the classic log-space fit.
    Used to build the approximation-based heSRPT benchmark.
    """
    th = np.linspace(theta_min, B, n)
    sv = np.array([float(s_fn(t)) for t in th])
    if method == "loglog":
        lx, ly = np.log(th), np.log(sv)
        p = float(np.cov(lx, ly, bias=True)[0, 1] / np.var(lx))
        a = float(np.exp(np.mean(ly) - p * np.mean(lx)))
        return a, p
    # grid over p with analytic a per p, then golden-zoom refine
    lo, hi = 0.02, 0.999

    def err_a(p):
        X = th ** p
        a = float(X @ sv / (X @ X))
        return float(np.sum((a * X - sv) ** 2)), a

    for _ in range(6):
        ps = np.linspace(lo, hi, 64)
        errs = [err_a(p)[0] for p in ps]
        i = int(np.argmin(errs))
        lo, hi = ps[max(i - 1, 0)], ps[min(i + 1, len(ps) - 1)]
    p = 0.5 * (lo + hi)
    return err_a(p)[1], p
