"""repro — SmartFill (Optimal Parallel Scheduling under Concave Speedup
Functions) as a production multi-pod JAX framework.

Subpackages: core (the paper), sched (cluster scheduler), models (10-arch
LM stack), kernels (Pallas TPU), distributed (sharding policies), train,
serve, data, configs, launch (mesh + dry-run + entry points).
"""
