"""Fleet-sharded planning + ensemble simulation on forced host devices.

Runs entirely on CPU: before jax initializes we force an 8-way host
"mesh" via XLA_FLAGS, then

  1. plan a 1000-instance SmartFill sweep sharded over the mesh
     (``plan_sharded``), streamed in bounded chunks;
  2. race three policies over a 256-workload ensemble sharded the same
     way (``simulate_ensemble_sharded``);
  3. cross-check both against the single-device paths — sharding is a
     layout decision, the numbers must agree.

Usage:
    PYTHONPATH=src python examples/fleet_sweep.py
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (import after the flag so 8 devices exist)

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import (log_speedup, sample_workloads,  # noqa: E402
                        simulate_ensemble, smartfill_batched)
from repro.distributed import (fleet_mesh, plan_sharded,  # noqa: E402
                               simulate_ensemble_sharded)
from repro.sched.policies import (EquiPolicy, HeSRPTPolicy,  # noqa: E402
                                  SmartFillPolicy)

B = 10.0


def main():
    mesh = fleet_mesh()
    print(f"mesh: {mesh.devices.size} devices, axes {mesh.axis_names}")

    # -- 1. sharded planning sweep, chunked streaming -------------------
    sp = log_speedup(1.0, 1.0, B)
    wl = sample_workloads(seed=0, K=1000, M=16, B=B, m_range=(4, 16))
    sched = plan_sharded(sp, wl.X, wl.W, B=B, mesh=mesh, chunk_size=200)
    J = np.asarray(sched.J)
    print(f"\nplanned {len(J)} instances in chunks of 200 over the mesh")
    print(f"  mean J = {J.mean():.4f}   max J = {J.max():.4f}")

    ref = smartfill_batched(sp, wl.X, wl.W, B=B)
    print(f"  max |J_sharded − J_single| = "
          f"{np.abs(J - np.asarray(ref.J)).max():.2e}")

    # -- 2. sharded policy face-off over a random ensemble --------------
    wl = sample_workloads(seed=1, K=256, M=8, B=B, m_range=(2, 8),
                          arrival_rate=0.5)
    policies = (SmartFillPolicy(sp, B=B), HeSRPTPolicy(0.5, B),
                EquiPolicy(B))
    res = simulate_ensemble_sharded(sp, policies, wl.X, wl.W,
                                    arrival=wl.arrival, B=B, mesh=mesh,
                                    chunk_size=64)
    ref = simulate_ensemble(sp, policies, wl.X, wl.W,
                            arrival=wl.arrival, B=B)
    print(f"\nsimulated {res.J.shape[1]} workloads × "
          f"{res.J.shape[0]} policies over the mesh")
    print(f"{'policy':>12s} {'mean J':>10s} {'vs OPT':>8s}")
    base = np.asarray(res.J[0])
    for p, name in enumerate(res.policy_names):
        Jp = np.asarray(res.J[p])
        print(f"{name:>12s} {Jp.mean():10.4f} {Jp.mean() / base.mean():8.3f}")
    print(f"  max |J_sharded − J_single| = "
          f"{np.abs(np.asarray(res.J) - np.asarray(ref.J)).max():.2e}")


if __name__ == "__main__":
    main()
