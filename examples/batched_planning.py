"""Fleet-scale planning: hundreds of scheduling instances in one call.

Three consumers of the batched SmartFill API:

  1. raw `smartfill_batched` — N independent (x, w, B) instances, padded
     to a common width, solved by a single vmap'd device program;
  2. `ClusterScheduler.current_allocations_fleets` — instantaneous
     re-planning for many tenant fleets at once;
  3. `serve.admission.AdmissionController` — admission control that
     scores every queued candidate's marginal ΔJ in one planning call.

Run: PYTHONPATH=src python examples/batched_planning.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import log_speedup, smartfill, smartfill_batched
from repro.sched.cluster import ClusterScheduler, Job
from repro.serve.admission import AdmissionController

B = 10.0
sp = log_speedup(1.0, 1.0, B)
rng = np.random.default_rng(0)

# --- 1. batched solve: 256 padded instances, one device call -------------
N, M = 256, 16
X = np.zeros((N, M))
W = np.zeros((N, M))
ms = rng.integers(2, M + 1, N)
for n in range(N):
    xs = np.sort(rng.uniform(0.5, 20.0, ms[n]))[::-1]
    X[n, : ms[n]] = xs
    W[n, : ms[n]] = 1.0 / xs

sched = smartfill_batched(sp, X, W, B=B)          # compile + solve
jax.block_until_ready(sched.J)
t0 = time.perf_counter()
sched = smartfill_batched(sp, X, W, B=B)
jax.block_until_ready(sched.J)
dt = time.perf_counter() - t0
print(f"batched: {N} instances (≤{M} jobs each) in {dt*1e3:.1f} ms "
      f"→ {N/dt:,.0f} instances/sec")

n0 = int(np.argmax(ms))
one = smartfill(sp, X[n0, : ms[n0]], W[n0, : ms[n0]], B=B)
print(f"spot-check vs sequential: |ΔJ|/J = "
      f"{abs(float(sched.J[n0]) - one.J) / one.J:.2e}")

# --- 2. cluster: re-plan many tenant fleets at once ----------------------
fleets = []
for _ in range(8):
    k = int(rng.integers(2, 7))
    sizes = np.sort(rng.uniform(50.0, 500.0, k))[::-1]
    fleets.append([Job(name=f"j{i}", size=float(s), weight=float(1.0 / s))
                   for i, s in enumerate(sizes)])
cs = ClusterScheduler(sp, B)
allocs = cs.current_allocations_fleets(fleets)
print(f"\ncluster: re-planned {len(fleets)} fleets in one call; "
      f"fleet 0 allocations = {np.round(allocs[0], 3)} (Σ = "
      f"{allocs[0].sum():.3f})")

# --- 3. serving: admission control by marginal planning cost -------------
running = np.array([9.0, 6.0, 3.0])
cands = rng.uniform(0.5, 15.0, 6)
ac = AdmissionController(sp, B)
dec = ac.evaluate(running, 1.0 / running, cands, 1.0 / cands)
print(f"\nadmission: baseline J = {dec.baseline_J:.3f}")
for i, (size, dj) in enumerate(zip(cands, dec.marginal_cost)):
    print(f"  candidate {i} (size {size:5.2f}) → ΔJ = {dj:8.4f}")
best = ac.admit_best(running, 1.0 / running, cands, 1.0 / cands, k=2)
print(f"admit (2 cheapest): {list(best)}")
