"""Policy face-off on the device scenario engine.

Evaluates the whole policy zoo over a seeded random workload ensemble —
P policies × K workloads in ONE compiled device call — and prints the
paper-§6-style comparison table: mean/median J, mean gap to SmartFill,
and how often each baseline ties the optimum.

    PYTHONPATH=src python examples/policy_faceoff.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import log_speedup, sample_workloads, simulate_ensemble
from repro.core.hesrpt import fit_power
from repro.sched.policies import default_zoo

B = 10.0
K, M = 128, 8


def main():
    sp = log_speedup(1.0, 1.0, B)          # parking speedup: SmartFill wins
    a_fit, p_fit = fit_power(lambda t: float(np.log1p(t)), B)
    wl = sample_workloads(seed=0, K=K, M=M, B=B, m_range=(3, M))
    zoo = default_zoo(sp, p_fit=p_fit)

    res = simulate_ensemble(sp, zoo, wl.X, wl.W, B=B)
    J = np.asarray(res.J)                  # (P, K)
    assert bool(np.all(np.asarray(res.finished)))

    print(f"s(θ) = ln(1+θ)  B={B}  K={K} workloads, M≤{M} jobs "
          f"(heSRPT fit: {a_fit:.2f}·θ^{p_fit:.2f})")
    print(f"{'policy':<12} {'mean J':>10} {'median J':>10} "
          f"{'gap vs SF':>10} {'ties SF':>8}")
    for p_i, name in enumerate(res.policy_names):
        gap = 100.0 * (J[p_i] - J[0]) / J[0]
        ties = np.mean(J[p_i] <= J[0] * (1 + 1e-9))
        print(f"{name:<12} {J[p_i].mean():>10.4f} "
              f"{np.median(J[p_i]):>10.4f} {gap.mean():>9.2f}% "
              f"{100 * ties:>7.0f}%")
    ev = int(np.asarray(res.n_events).sum())
    print(f"\n{len(zoo)}×{K} = {len(zoo) * K} simulations, "
          f"{ev} events, one compiled call.")


if __name__ == "__main__":
    main()
