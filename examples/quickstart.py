"""Quickstart: the paper in 40 lines.

Schedules 8 parallel jobs on a divisible server (B = 10) under a concave
speedup s(θ) = log(1+θ) — a *regular* function with s'(0) < ∞, i.e. the
case heSRPT cannot handle optimally — and prints the SmartFill schedule,
the CDR constants, and the comparison against approximation-based heSRPT.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (cdr_violation, fit_power, hesrpt_policy, log_speedup,
                        simulate_policy, smartfill)

B = 10.0
M = 8
x = np.arange(M, 0, -1.0) * 2.0      # job sizes (non-increasing)
w = 1.0 / x                           # mean-slowdown weights

sp = log_speedup(1.0, 1.0, B)
sched = smartfill(sp, x, w, B=B)

print("=== SmartFill schedule (Θ[i,j] = rate of job i in phase j) ===")
th = np.asarray(sched.theta)
print(np.array_str(th, precision=2, suppress_small=True))
print("\nphase durations:", np.array_str(np.asarray(sched.durations), precision=3))
print("completion times:", np.array_str(np.asarray(sched.T), precision=3))
print("CDR constants c:", np.array_str(np.asarray(sched.c), precision=4))
print(f"\noptimal J = Σ wᵢTᵢ = {sched.J:.4f}"
      f"   (Prop. 9 check: Σ aᵢxᵢ = {sched.J_linear:.4f})")

parked = [(i + 1, j + 1) for j in range(M) for i in range(j + 1)
          if th[i, j] == 0.0]
print(f"parked (job, phase) pairs — the behavior heSRPT cannot express: "
      f"{parked}")

v = cdr_violation(sp, sched.theta)
print(f"CDR rule violation: ratio={v['ratio']:.2e} park={v['park']:.2e}")

a_fit, p_fit = fit_power(lambda t: np.log1p(t), B)
res = simulate_policy(sp, x, w, hesrpt_policy(p_fit, B))
print(f"\nheSRPT (fit {a_fit:.2f}·θ^{p_fit:.2f}) J = {res.J:.4f}"
      f"  → SmartFill is {100 * (res.J - sched.J) / res.J:.1f}% better")
