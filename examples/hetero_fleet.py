"""One pod, ten model shapes, ten *different* speedup functions.

The paper-§7 payoff scenario: every architecture in ``configs/`` gets
its own roofline-calibrated speedup (compute-vs-allreduce balance →
Table-1-row-3 regular function via ``sched/speedup_models.py``), the ten
functions are stacked into one job-indexed speedup, and heterogeneous
SmartFill plans a single 256-chip pod across all of them — something the
shared-function solver could not express at all.

Shows: the searched completion order (≠ plain size order), the first
phase's allocations under each job's own scaling curve, and the gap to
(a) the retired weighted-marginal-rate heuristic and (b) planning with
one averaged speedup.

Run: PYTHONPATH=src python examples/hetero_fleet.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.configs import get_config, list_archs
from repro.core import simulate_policy_device, smartfill_hetero, stack_speedups
from repro.sched.policies import WeightedMarginalRatePolicy
from repro.sched.speedup_models import job_speedup

B_CHIPS = 256.0
TOKENS_PER_STEP = 256 * 4096        # the train_4k shape

# --- 1. one calibrated speedup per architecture -----------------------------
archs = sorted(list_archs())
members, names = [], []
for arch in archs:
    cfg = get_config(arch)
    step_flops = 6.0 * cfg.active_param_count() * TOKENS_PER_STEP
    grad_bytes = 2.0 * cfg.param_count()          # bf16 gradient all-reduce
    members.append(job_speedup(step_flops=step_flops, grad_bytes=grad_bytes,
                               tokens_per_step=TOKENS_PER_STEP, B=B_CHIPS))
    names.append(arch)
sp = stack_speedups(members, B=B_CHIPS)
M = len(names)

rng = np.random.default_rng(0)
x = rng.uniform(2, 15, M) * 1e9                   # tokens of work remaining
# Heterogeneous slowdown weights: 1 / solo completion time, i.e.
# w_i = s_i(B)/x_i.  This is the §7 analogue of the paper's agreeable
# w = 1/x — weights non-decreasing along the *normalized*-size order.
# (Weights decoupled from the normalized sizes can make the instance
# non-agreeable in normalized terms, where the adjacent-exchange order
# search can stall at an unrealized order; pass exchange_window=2 to
# smartfill_hetero to score all distance-≤2 swaps per step — the
# batched scorer prices them in one vmapped solve, and
# tests/core/test_hetero_fast.py pins an instance where the wider
# window recovers ~16% J.  Beyond-window moves: see ROADMAP open items.)
w = np.array([float(m.s(B_CHIPS)) for m in members]) / x

print(f"{M} jobs on one {int(B_CHIPS)}-chip pod — per-job roofline speedups")
print(f"{'arch':>22s} {'params':>8s} {'work(Gtok)':>10s} "
      f"{'s(B) tok/s':>11s}")
for i, n in enumerate(names):
    print(f"{n:>22s} {get_config(n).param_count() / 1e9:7.1f}B "
          f"{x[i] / 1e9:10.2f} {float(members[i].s(B_CHIPS)):11.3g}")

# --- 2. heterogeneous SmartFill plan ----------------------------------------
plan = smartfill_hetero(sp, x, w, B=B_CHIPS, exchange_passes=2)
size_order = np.argsort(-x)
print(f"\nhetero plan J* = {plan.J:.6g}   (J == Σ aᵢxᵢ: "
      f"{abs(plan.J - plan.J_linear) / plan.J:.1e} — order realized)")
print("completion order (first row completes last):")
print("  by normalized size:", [names[i] for i in plan.order])
print("  by plain size:     ", [names[i] for i in size_order])

theta0 = np.asarray(plan.theta)[:, -1]            # earliest phase, all live
print("\nfirst-phase chips per job (its own speedup sets its share):")
for r, oi in enumerate(plan.order):
    print(f"  {names[oi]:>22s}: {theta0[r]:7.1f} chips")

# --- 3. baselines ------------------------------------------------------------
res = simulate_policy_device(sp, x, w, WeightedMarginalRatePolicy(sp, B=B_CHIPS),
                             B=B_CHIPS)
print(f"\nretired weighted-marginal-rate heuristic J = {res.J:.6g} "
      f"(+{(res.J / plan.J - 1) * 100:.2f}% vs hetero SmartFill)")

avg = stack_speedups([members[0]] * M, B=B_CHIPS)  # pretend all jobs scale
avg_plan = smartfill_hetero(avg, x, w, B=B_CHIPS)  # like the first one
print(f"one-speedup-fits-all plan (under job 0's curve) claims "
      f"J = {avg_plan.J:.6g} — the per-job curves are what make the "
      "plan honest")
