"""End-to-end training driver: any assigned arch, any scale preset.

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-1b \
        --preset tiny --steps 300

Presets: tiny (CPU-friendly ~1M params), small (~20M), 100m (~100M —
hours on CPU, what you would run on a real slice).  Uses the production
substrate end to end: deterministic sharded data, AdamW + cosine,
microbatching, NaN-guard, periodic async checkpoints, restart-resume.
"""
import argparse
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticTokens, host_batch_iterator
from repro.models import init_params
from repro.train import (AdamWConfig, CheckpointHook, TrainState,
                         checkpoint as ckpt, make_train_step, train_loop)

PRESETS = {
    "tiny": dict(n_layers=2, d_model=64, d_ff=128, vocab=512, heads=4),
    "small": dict(n_layers=4, d_model=256, d_ff=1024, vocab=4096, heads=8),
    "100m": dict(n_layers=12, d_model=768, d_ff=3072, vocab=32768, heads=12),
}


def scaled_config(arch, preset):
    cfg = get_config(arch, smoke=True)
    p = PRESETS[preset]
    kv = max(1, p["heads"] // max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)) \
        if cfg.n_heads else 0
    over = dict(n_layers=p["n_layers"], d_model=p["d_model"],
                d_ff=p["d_ff"] if cfg.d_ff else 0, vocab=p["vocab"],
                dtype="float32")
    if cfg.n_heads:
        over.update(n_heads=p["heads"], n_kv_heads=kv,
                    head_dim=p["d_model"] // p["heads"])
    if cfg.moe:
        over.update(d_ff_expert=p["d_ff"] // 4)
    if cfg.lru_width:
        over.update(lru_width=p["d_model"])
    if cfg.dt_rank:
        over.update(dt_rank=max(8, p["d_model"] // 16))
    return cfg.replace(**over)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = scaled_config(args.arch, args.preset)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={args.arch} preset={args.preset} params={n_params:,}")

    state = TrainState.create(params)
    opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    start = 0
    if args.resume and ckpt.latest(args.ckpt_dir):
        tree, manifest = ckpt.restore(
            ckpt.latest(args.ckpt_dir),
            {"params": state.params, "opt": state.opt_state})
        state.params, state.opt_state = tree["params"], tree["opt"]
        state.step = start = manifest["step"]
        print(f"resumed from step {start}")

    src = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    it = host_batch_iterator(src, cfg, start_step=start)
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      microbatches=args.microbatches))
    hooks = [CheckpointHook(args.ckpt_dir, every=100)]
    hist = train_loop(cfg, opt, state, it, args.steps - start,
                      train_step=step_fn, hooks=hooks, log_every=25)
    l0 = np.mean([h["loss"] for h in hist[:10]])
    l1 = np.mean([h["loss"] for h in hist[-10:]])
    tps = args.batch * args.seq / np.median([h["step_time_s"] for h in hist])
    print(f"\nloss {l0:.3f} → {l1:.3f} | ~{tps:,.0f} tokens/s host throughput")


if __name__ == "__main__":
    main()
