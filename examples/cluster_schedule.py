"""The paper on a TPU pod, end to end.

1. Calibrate per-job speedup functions from the dry-run's roofline terms
   (a DP training job's s(θ) is Table-1-row-3 *regular* — closed form).
2. Plan with SmartFill; show which jobs it parks (heSRPT can't).
3. Simulate the plan with real-world costs: reallocation = checkpoint +
   mesh swap + restore, integer chips.
4. Execute one reallocation event for REAL on a smoke-scale model via
   sched/elastic.py — checkpoint, mesh re-instantiation, reshard-restore.

Run: PYTHONPATH=src python examples/cluster_schedule.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import os
import tempfile

import numpy as np

from repro.configs import get_config
from repro.core import smartfill
from repro.data import SyntheticTokens, host_batch_iterator
from repro.models import init_params
from repro.sched import ClusterScheduler, ElasticTrainer, Job
from repro.sched.speedup_models import calibrate_from_dryrun, job_speedup
from repro.train import AdamWConfig, TrainState, make_train_step

B_CHIPS = 256.0

# --- 1. calibrated speedups -------------------------------------------------
if os.path.exists("dryrun_single_pod.json"):
    cal = calibrate_from_dryrun("dryrun_single_pod.json", B=B_CHIPS)
    sp = cal[("deepseek-7b", "train_4k")]
    print("speedup calibrated from dry-run roofline terms "
          "(deepseek-7b train_4k)")
else:
    sp = job_speedup(step_flops=6 * 7e9 * 1e6, grad_bytes=2 * 7e9,
                     tokens_per_step=1e6, B=B_CHIPS)
    print("speedup from analytic roofline (no dry-run json found)")
print(f"  s(32)={float(sp.s(32.)):.3g}  s(128)={float(sp.s(128.)):.3g}  "
      f"s(256)={float(sp.s(256.)):.3g} tokens/s — concave, s'(0) finite")

# --- 2. SmartFill plan -------------------------------------------------------
rng = np.random.default_rng(1)
M = 6
work = np.sort(rng.uniform(2, 15, M))[::-1] * 1e9          # tokens
weights = 1.0 / work
sched = smartfill(sp, work, weights, B=B_CHIPS)
th = np.asarray(sched.theta)
print(f"\nSmartFill plan for {M} jobs on {int(B_CHIPS)} chips "
      f"(J*={sched.J:.4g}):")
for j in range(M):
    alloc = ", ".join(f"{th[i, j]:7.1f}" for i in range(j + 1))
    print(f"  phase {j + 1} ({sched.durations[j]:8.1f}s): [{alloc}]")
parked = sum(1 for jj in range(M) for i in range(jj + 1) if th[i, jj] == 0)
print(f"  parked job-phases: {parked} (SmartFill's selectivity)")

# --- 3. cluster simulation with real costs ----------------------------------
jobs = [Job(name=f"run{i}", size=float(work[i]), weight=float(weights[i]))
        for i in range(M)]
cs = ClusterScheduler(sp, B_CHIPS, realloc_cost_s=30.0, min_delta=2.0,
                      integer_chips=True)
events, J = cs.simulate(jobs)
print(f"\nsimulated with 30s reallocation cost + integer chips: "
      f"J={J:.4g} ({100 * (J - sched.J) / sched.J:.2f}% over ideal)")

# --- 4. one real elastic reallocation ----------------------------------------
cfg = get_config("llama3.2-1b", smoke=True)
params = init_params(jax.random.PRNGKey(0), cfg)
state = TrainState.create(params)
step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
src = SyntheticTokens(vocab=cfg.vocab, seq_len=64, global_batch=8)
it = host_batch_iterator(src, cfg)
for _ in range(3):
    state.params, state.opt_state, m = step(state.params, state.opt_state,
                                            next(it))
    state.step += 1
with tempfile.TemporaryDirectory() as d:
    trainer = ElasticTrainer(cfg, lambda mesh: step, d)
    new_mesh, state = trainer.reallocate(state, old_chips=128, new_chips=64)
    ev = trainer.events[0]
    print(f"\nexecuted SmartFill reallocation 128→64 chips for real: "
          f"ckpt+mesh-swap+reshard-restore in {ev.restore_s * 1e3:.0f} ms "
          f"(smoke-scale model)")
    state.params, state.opt_state, m = step(state.params, state.opt_state,
                                            next(it))
    print(f"training resumed, loss={float(m['loss']):.4f} — "
          f"elasticity and fault recovery share this one code path")
