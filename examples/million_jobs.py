"""One million jobs planned on a laptop CPU: class-aggregated SmartFill.

Per-job planning tops out around M=256 rows (the bench ceiling); a
production controller for millions of users plans over *classes*.  A
class is (job count n_c, per-job size x_c, per-job weight w_c, a
Table-1 speedup family), and the exact identity

    S_c(Θ) = n_c · s_c(Θ / n_c)     (same family: A → A·n^{−γ}, w → n·w)

turns C classes into a C-row §7 heterogeneous instance — so M = 10⁶
jobs cost one C ≲ 64-row solve.  This demo:

  1. plans M = 1,000,000 jobs as C = 32 classes and times the solve;
  2. shows the convergence anchor — at one job per class the class
     plan IS the per-job SmartFill plan (exactly, not approximately);
  3. drains the plan through the fluid-limit simulator (class counts
     decrease continuously) and confirms the executed objective
     reproduces the plan's J;
  4. plans a whole batch of class instances in one device call.

Run: PYTHONPATH=src python examples/million_jobs.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (plan_classes, plan_classes_batched,
                        sample_class_workloads, simulate_fluid_classes,
                        smartfill_hetero)
from repro.sched.policies import ClassSmartFillPolicy

B = 10.0

# --- 1. one million jobs as 32 classes -----------------------------------
C, per = 32, 31_250                    # 32 × 31,250 = 1,000,000 jobs
wl = sample_class_workloads(1, K=1, C=C, B=B, count_range=(per, per))
state = wl.state(0)
print(f"instance: M = {state.jobs:,.0f} jobs in C = {state.C} classes, "
      f"mixed speedup families (σ = ±1)")

t0 = time.perf_counter()
plan = plan_classes(state)             # compile + solve
dt_cold = time.perf_counter() - t0
t0 = time.perf_counter()
plan = plan_classes(state)
dt_warm = time.perf_counter() - t0
print(f"plan_classes: {dt_cold:.1f} s cold (compile), "
      f"{dt_warm*1e3:.0f} ms warm → "
      f"{state.jobs/dt_warm:,.0f} jobs/sec planned")
print(f"J = {plan.J:.4e}  (certificate |J - J_linear|/J = "
      f"{abs(plan.J - plan.J_linear)/plan.J:.1e})")

# --- 2. the convergence anchor: 1 job/class ≡ per-job SmartFill ----------
wl1 = sample_class_workloads(5, K=1, C=8, B=B, count_range=(1, 1))
s1 = wl1.state(0)
cls = plan_classes(s1)
per_job = smartfill_hetero(s1.sp, s1.sizes, s1.weights, B=B,
                           coarse=64, descent_iters=96, cap_iters=64,
                           stol_rel=1e-10)
print(f"\n1 job/class, C = 8: class J = {cls.J:.12e}")
print(f"          per-job J = {float(per_job.J):.12e}  "
      f"(identical: {cls.J == float(per_job.J)})")

# --- 3. execute the plan in the fluid limit ------------------------------
policy = ClassSmartFillPolicy.from_classes(state, pin=True, cache_plan=True)
res = simulate_fluid_classes(state, policy)
print(f"\nfluid drain: {res.n_events} events, finished = {res.finished}")
print(f"executed J = {res.J_jobs:.4e}  "
      f"(|ΔJ|/J vs plan = {abs(res.J_jobs - plan.J)/plan.J:.1e})")
print(f"fluid-mass objective J_fluid = {res.J_fluid:.4e} ≤ J_jobs")

# --- 4. a fleet of class instances in one batched call -------------------
K = 64
wlk = sample_class_workloads(7, K=K, C=16, B=B, count_range=(0, 50_000))
orders, sched = plan_classes_batched(wlk.counts, wlk.sizes, wlk.weights,
                                     wlk.sp, B=B)
jax.block_until_ready(sched.J)
t0 = time.perf_counter()
orders, sched = plan_classes_batched(wlk.counts, wlk.sizes, wlk.weights,
                                     wlk.sp, B=B)
jax.block_until_ready(sched.J)
dt = time.perf_counter() - t0
total_jobs = float(wlk.jobs.sum())
print(f"\nbatched: {K} instances, {total_jobs:,.0f} jobs total "
      f"in {dt*1e3:.1f} ms → {total_jobs/dt:,.0f} jobs/sec")
