"""Batched serving example: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-27b \
        --batch 4 --prompt-len 64 --gen 32

Uses the smoke-scale config of the chosen arch (full configs are
exercised via the dry-run); demonstrates ring-buffer local attention,
GQA KV caches and SSM-state decode on whichever family you pick.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.moe:
        cfg = cfg.replace(moe_impl="dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg=cfg, params=params,
                      max_len=args.prompt_len + args.gen,
                      temperature=args.temperature)

    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(
        2, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (args.batch, cfg.n_patches, cfg.patch_dim)).astype(np.float32)
    if cfg.encoder_decoder:
        batch["frames"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.patch_dim)).astype(np.float32)

    t0 = time.perf_counter()
    out = eng.generate(batch, args.gen)
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0][:16], "…")


if __name__ == "__main__":
    main()
