"""Roofline report: formats the dry-run JSONs into the §Roofline table.

Reads dryrun_single_pod.json (the per-cell compute/memory/collective
terms derived from the compiled HLO) and emits the markdown table plus
per-cell one-line diagnoses used in EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

DIAGNOSIS = {
    "compute": "MXU-bound — push block shapes / overlap collectives",
    "memory": "HBM-bound — fuse attention/scan traffic (Pallas kernels), "
              "cut f32 round-trips",
    "collective": "ICI-bound — reshard (less TP / more DP), compress or "
                  "overlap collectives",
}


def load(path="dryrun_single_pod.json"):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [r for r in json.load(f) if r.get("ok")]


def table(rows):
    out = ["| arch | shape | compute_s | memory_s (fused) | collective_s | "
           "bottleneck | useful% | temp GiB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} ({r.get('memory_fused_s', 0):.3f}) | "
            f"{r['collective_s']:.3f} | {r['bottleneck']} | "
            f"{100*r['useful_flops_ratio']:.0f} | "
            f"{r['temp_bytes_per_dev']/2**30:.1f} |")
    return "\n".join(out)


def summary_rows(rows):
    out = []
    for r in rows:
        dom = r["bottleneck"]
        frac = r["compute_s"] / max(r["compute_s"], r["memory_s"],
                                    r["collective_s"])
        out.append({
            "name": f"roofline_{r['arch']}_{r['shape']}",
            "us_per_call": r[f"{dom}_s"] * 1e6,
            "derived": f"bottleneck={dom};roofline_frac={frac:.3f};"
                       f"useful={r['useful_flops_ratio']:.2f};"
                       f"diag={DIAGNOSIS[dom]}",
        })
    return out
