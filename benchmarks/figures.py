"""Paper-figure reproductions (one function per figure).

Fig. 4: s(θ)=θ^0.5      — SmartFill ≡ heSRPT (optimal on its home turf)
Fig. 5: s(θ)=10θ^0.8    — same, scaled family
Fig. 6: s(θ)=log(1+θ)   — SmartFill beats approximation-based heSRPT
Fig. 7: the 0.79·θ^0.48 fit heSRPT uses for Fig. 6
Fig. 8: s(θ)=√(4+θ)−2   — SmartFill beats heSRPT (tighter fit → smaller gap)
Fig. 9: the 0.26·θ^0.82 fit heSRPT uses for Fig. 8

Benchmark setting = paper §6: B = 10, x_i = M−i+1, w_i = 1/x_i (mean
slowdown), M ∈ {10, …, 100}.  The heSRPT baseline re-plans at true
completion events (the strong reading of "apply heSRPT with an
approximate s"); the open-loop reading is reported alongside as a
bracket — see EXPERIMENTS.md §Repro for the discussion.
"""
from __future__ import annotations

import numpy as np

from repro.core import (fit_power, hesrpt_policy, log_speedup, power,
                        shifted_power, simulate_policy, smartfill)
from repro.core.hesrpt import hesrpt_open_loop

B = 10.0
MS = (10, 20, 30, 40, 50, 60, 70, 80, 90, 100)


def _slowdown_instance(M):
    x = np.arange(M, 0, -1.0)
    return x, 1.0 / x


def _sweep(sp, p_fit, a_fit, ms=MS, open_loop=False):
    rows = []
    for M in ms:
        x, w = _slowdown_instance(M)
        # fast_path=False: figs. 4/5 exist to show Algorithm 2's *numeric
        # minimizer* reproduces heSRPT — the closed-form fast path would
        # compute μ* with heSRPT's own formula and validate nothing.
        sf = smartfill(sp, x, w, B=B, fast_path=False)
        he = simulate_policy(sp, x, w, hesrpt_policy(p_fit, B))
        row = {"M": M, "smartfill_J": sf.J, "hesrpt_J": he.J,
               "gap_pct": 100 * (he.J - sf.J) / he.J}
        if open_loop:
            _, Jol = hesrpt_open_loop(sp, x, w, p_fit, a_fit, B)
            row["hesrpt_openloop_J"] = Jol
            row["gap_openloop_pct"] = 100 * (Jol - sf.J) / Jol
        rows.append(row)
    return rows


def fig4(ms=MS):
    """s=θ^0.5: SmartFill must equal heSRPT (both optimal)."""
    return _sweep(power(1.0, 0.5, B), 0.5, 1.0, ms)


def fig5(ms=MS):
    """s=10θ^0.8."""
    return _sweep(power(10.0, 0.8, B), 0.8, 10.0, ms)


def fig6(ms=MS):
    """s=log(1+θ) vs heSRPT with the paper's 0.79θ^0.48 fit."""
    return _sweep(log_speedup(1.0, 1.0, B), 0.48, 0.79, ms, open_loop=True)


def fig7():
    """Reproduce the power-law fit of log(1+θ)."""
    a, p = fit_power(lambda t: np.log1p(t), B)
    return [{"target": "log(1+th)", "a_fit": a, "p_fit": p,
             "paper_a": 0.79, "paper_p": 0.48}]


def fig8(ms=MS):
    """s=√(4+θ)−2 vs heSRPT with the paper's 0.26θ^0.82 fit."""
    return _sweep(shifted_power(1.0, 4.0, 0.5, B), 0.82, 0.26, ms,
                  open_loop=True)


def fig9():
    a, p = fit_power(lambda t: np.sqrt(4 + t) - 2, B)
    return [{"target": "sqrt(4+th)-2", "a_fit": a, "p_fit": p,
             "paper_a": 0.26, "paper_p": 0.82}]


ALL = {"fig4": fig4, "fig5": fig5, "fig6": fig6, "fig7": fig7,
       "fig8": fig8, "fig9": fig9}
