"""Streaming control-plane benchmarks: the online service loop.

Five questions, one JSON:

  * **Sustained service throughput** — ``serve_stream_day`` runs the
    device-resident event scan (``StreamController.run_device``: the
    whole day is one compiled ``lax.scan`` over control-plane events)
    over a day-long diurnal arrival trace (arrivals + budget dips +
    recoveries) and reports control-plane events/sec sustained end to
    end, plus the SLO tail and its bit-parity against the host oracle
    (``run`` with the same ``StreamCascadePolicy``), which is timed as
    ``serve_stream_day_host``.  This is the number a capacity planner
    quotes: how much open-arrival load one controller absorbs.

  * **Multi-tenant sharding** — ``serve_multitenant_*_T{1,2,4,8}``
    runs T independent tenant streams through
    ``serve_streams_sharded`` on T forced host devices (each count in
    its own subprocess, like perf_core's fleet rows) with fixed
    per-tenant load — ideal weak scaling is T× the T=1 events/sec.

  * **Trace replay** — ``serve_trace_replay`` replays the recorded
    arrival log under ``benchmarks/traces/`` through the controller
    via ``load_arrival_log`` (the production-trace path, vs the
    synthetic sampler every other row uses).

  * **Warm vs cold replanning** — ``serve_warm_replan_M*`` times one
    incremental replan (carried completion order + λ-bracket hints)
    against ``serve_cold_replan_M*``, the from-scratch solve on the
    same live state (fresh ranking plus, for per-job speedups, the full
    §7 exchange-order search a cold planner cannot skip).  The ratio is
    the ``serve_warm_vs_cold_replan_x`` headline — the reason the
    streaming controller replans every event without falling behind.

  * **Admission scoring** — ``serve_admission_score`` times one
    watchdog-wrapped marginal-ΔJ admission decision against a live set
    (``agreeable="rank"`` streaming mode).

Run directly to write ``BENCH_serve.json``:
    PYTHONPATH=src python -m benchmarks.perf_serve [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import power, sample_arrival_stream, sample_workloads
from repro.core.workloads import load_arrival_log
from repro.sched.policies import StreamingSmartFillPolicy
from repro.serve import StreamCascadePolicy, StreamController
from repro.serve.admission import AdmissionController

B = 10.0
SP = power(1.0, 0.5, B)
HETERO_FAMILIES = ("power", "shifted", "log", "neg_power", "saturating")


def _time(fn, *args, reps=100, warmup=3):
    """Best-of-reps warm latency in µs (see perf_core._time)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # µs


def bench_calibration():
    """Fixed-work machine-speed probe for the regression gate (identical
    in spirit to perf_core's: touches none of the serving code)."""
    x = jnp.ones((384, 384), jnp.float32)
    f = jax.jit(lambda x: (x @ x @ x).sum())
    return [{"name": "calibration_fixed_work", "us_per_call": _time(f, x)}]


def _stream_row(name, res, best, horizon):
    m = res.metrics
    return {
        "name": name,
        "us_per_call": best * 1e6,
        "horizon_s": horizon,
        "arrivals": m.n_arrivals,
        "completed": m.n_completed,
        "events": res.n_events,
        "replans": res.replans,
        "warm_replans": res.warm_replans,
        "cold_replans": res.cold_replans,
        "degraded_windows": res.degraded_windows,
        "events_per_sec": res.n_events / best,
        "arrivals_per_sec": m.n_arrivals / best,
        "weighted_J": m.weighted_J,
        "mean_slowdown": m.mean_slowdown,
        "p50_latency_s": m.p50_latency,
        "p99_latency_s": m.p99_latency,
        "deadline_misses": m.deadline_misses,
    }


def bench_stream(quick: bool = False):
    """The day-long open-arrival run: sustained events/s + SLO tail.

    Load is ~0.6 of service capacity at the diurnal peak, so the live
    set breathes between empty and full — the regime where warm starts,
    slot recycling, and budget-dip replans all fire.  quick mode runs
    two hours of trace instead of 24 (same mechanics, tier-1 friendly).

    ``serve_stream_day`` is the device-resident scan; the host loop
    with the same ``StreamCascadePolicy`` is its differential oracle
    and is timed alongside as ``serve_stream_day_host`` — the row pair
    is the hot-path speedup, and the device row carries the measured
    completion-array parity against the oracle (must be ~0).
    """
    horizon = 7_200.0 if quick else 86_400.0
    M = 8 if quick else 16
    stream = sample_arrival_stream(
        17, horizon=horizon, rate=0.12, diurnal=0.75, period=horizon,
        B=B, n_budget_events=2 if quick else 12,
        budget_frac=(0.3, 0.8), deadline_slack=50.0)
    ctl = StreamController(SP, B, max_live=M,
                           policy=StreamCascadePolicy(SP, B))

    def run_host():
        return ctl.run(stream)

    def run_dev():
        return ctl.run_device(stream)

    host = run_host()                             # warm the exec jit
    reps = 2 if quick else 1
    best_h = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        host = run_host()
        best_h = min(best_h, time.perf_counter() - t0)
    dev = run_dev()                               # compile + warm
    best_d = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        dev = run_dev()
        best_d = min(best_d, time.perf_counter() - t0)
    parity = float(np.max(np.abs(
        np.where(np.isfinite(host.completion), host.completion, 0.0)
        - np.where(np.isfinite(dev.completion), dev.completion, 0.0))))
    q = "_quick" if quick else ""
    day = _stream_row(f"serve_stream_day{q}", dev, best_d, horizon)
    day["parity_max_completion_diff"] = parity
    day["parity_dJ"] = abs(host.metrics.weighted_J
                           - dev.metrics.weighted_J)
    host_row = _stream_row(f"serve_stream_day_host{q}", host, best_h,
                           horizon)
    return [day, host_row]


MULTITENANT_COUNTS = (1, 2, 4, 8)


def bench_multitenant_worker(tenants: int, quick: bool) -> list:
    """Measure the sharded multi-tenant serve on THIS process's devices.

    Runs inside a subprocess whose XLA_FLAGS forced ``tenants`` host
    devices (one tenant per device).  Weak scaling: per-tenant load is
    fixed, so ideal total events/sec grows linearly with T — on runners
    with fewer physical cores than T the curve flattens at the core
    count, which is why the regression gate scopes these rows with
    ``--min-devices`` (see check_regression.py).
    """
    from repro.distributed import fleet_mesh, serve_streams_sharded

    if len(jax.devices()) != tenants:
        raise RuntimeError(
            f"multitenant worker expected {tenants} devices, found "
            f"{len(jax.devices())} — XLA_FLAGS not applied?")
    horizon = 1_800.0 if quick else 7_200.0
    streams = [sample_arrival_stream(
        17 + i, horizon=horizon, rate=0.12, diurnal=0.75, period=horizon,
        B=B, n_budget_events=2, budget_frac=(0.3, 0.8),
        deadline_slack=50.0) for i in range(tenants)]
    mesh = fleet_mesh()

    def run():
        return serve_streams_sharded(SP, streams, max_live=8, mesh=mesh)

    fleet = run()                                 # compile + warm
    best = float("inf")
    for _ in range(3 if quick else 2):
        t0 = time.perf_counter()
        fleet = run()
        best = min(best, time.perf_counter() - t0)
    events = sum(r.n_events for r in fleet.results)
    q = "_quick" if quick else "_day"
    return [{
        "name": f"serve_multitenant{q}_T{tenants}",
        "tenants": tenants,
        "us_per_call": best * 1e6,
        "horizon_s": horizon,
        "events": events,
        "events_per_sec": events / best,
        "arrivals": sum(r.metrics.n_arrivals for r in fleet.results),
        "completed": sum(r.metrics.n_completed for r in fleet.results),
        "mean_slowdown": float(np.mean(fleet.mean_slowdown)),
        "suggested_budget_share": fleet.suggested_budget_share.tolist(),
    }]


def bench_multitenant(quick: bool = False):
    """Weak-scaling rows: sharded tenants at 1/2/4/8 forced host devices.

    Each tenant count runs in its own subprocess because
    ``--xla_force_host_platform_device_count`` only takes effect before
    jax initializes (same pattern as perf_core.bench_fleet)."""
    repo = pathlib.Path(__file__).resolve().parent.parent
    rows = []
    for T in MULTITENANT_COUNTS:
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={T}").strip()
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = (str(repo / "src") + os.pathsep
                             + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
        cmd = [sys.executable, "-m", "benchmarks.perf_serve",
               "--multitenant-worker", str(T)]
        if quick:
            cmd.append("--quick")
        out = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                             text=True)
        if out.returncode != 0:
            raise RuntimeError(
                f"multitenant worker T={T} failed:\n{out.stderr[-2000:]}")
        rows.extend(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def bench_replay(quick: bool = False):
    """Replay the recorded trace under benchmarks/traces/ — the
    production-log ingestion path (``load_arrival_log`` →
    ``arrival_stream_from_log`` → controller), host loop."""
    path = pathlib.Path(__file__).parent / "traces" / "arrivals_sample.csv"
    stream = load_arrival_log(path)
    ctl = StreamController(SP, B, max_live=8,
                           policy=StreamCascadePolicy(SP, B))

    def run():
        return ctl.run(stream)

    res = run()                                   # warm
    best = float("inf")
    for _ in range(2 if quick else 3):
        t0 = time.perf_counter()
        res = run()
        best = min(best, time.perf_counter() - t0)
    row = _stream_row("serve_trace_replay", res, best, stream.horizon)
    row["trace"] = path.name
    return [row]


def bench_replan(quick: bool = False):
    """Warm vs cold replanning latency on the same live state.

    Per-job speedups are the honest comparison: a cold replan must
    re-make the §7 completion-order decision (exchange search over the
    live set), while the warm replan reuses the carried order and the
    validated λ payload — one hinted fixed-shape solve.  The shared-
    speedup pair is reported too (there the cold path is only a fresh
    ranking + unhinted solve, so the gap is the λ iterations alone).
    """
    rows = []
    reps = 10 if quick else 20
    for M in ((8,) if quick else (8, 16)):
        wl = sample_workloads(5, K=1, M=M, B=B, family=HETERO_FAMILIES,
                              per_job=True)
        sp1 = jax.tree_util.tree_map(lambda l: jnp.asarray(l)[0], wl.sp)
        x, w = np.asarray(wl.X[0]), np.asarray(wl.W[0])
        act = x > 0

        warm_pol = StreamingSmartFillPolicy(sp1, B)
        warm_pol.plan(x, w, act)                  # prime carried state

        def run_warm():
            return warm_pol.plan(x, w, act)

        def run_cold():
            return warm_pol.plan(x, w, act, warm=False)

        run_warm(); run_cold()                    # compile both paths
        us_w = _time(run_warm, reps=reps, warmup=1)
        us_c = _time(run_cold, reps=max(3, reps // 2), warmup=1)
        pw = run_warm()
        assert pw.warm and pw.certified
        rows.append({"name": f"serve_warm_replan_M{M}", "M": M,
                     "us_per_call": us_w, "J": pw.J})
        rows.append({"name": f"serve_cold_replan_M{M}", "M": M,
                     "us_per_call": us_c, "J": run_cold().J})

    # shared-speedup pair at M=16: the λ-hint-only gap
    M = 16
    x = np.arange(M, 0, -1.0)
    w = 1.0 / x
    act = np.ones(M, bool)
    pol = StreamingSmartFillPolicy(SP, B)
    pol.plan(x, w, act)

    def run_warm_sh():
        return pol.plan(x, w, act)

    def run_cold_sh():
        return pol.plan(x, w, act, warm=False)

    run_warm_sh(); run_cold_sh()
    rows.append({"name": f"serve_warm_replan_shared_M{M}", "M": M,
                 "us_per_call": _time(run_warm_sh, reps=reps, warmup=1)})
    rows.append({"name": f"serve_cold_replan_shared_M{M}", "M": M,
                 "us_per_call": _time(run_cold_sh, reps=reps, warmup=1)})
    return rows


def bench_admission(quick: bool = False):
    """One watchdog-wrapped admission decision against a live set."""
    M = 8 if quick else 15
    rng = np.random.default_rng(2)
    run_x = np.sort(rng.uniform(0.5, 20.0, M))[::-1].copy()
    run_w = 1.0 / run_x
    cand_x = np.asarray([rng.uniform(0.5, 20.0)])
    cand_w = 1.0 / cand_x
    adm = AdmissionController(SP, B=B, agreeable="rank")

    def run():
        return adm.evaluate(run_x, run_w, cand_x, cand_w)

    run()                                         # compile + warm
    return [{"name": f"serve_admission_score_M{M}", "M": M,
             "us_per_call": _time(run, reps=10 if quick else 30,
                                  warmup=1)}]


def collect(quick: bool = False):
    stream = bench_stream(quick=quick)
    multitenant = bench_multitenant(quick=quick)
    replay = bench_replay(quick=quick)
    replan = bench_replan(quick=quick)
    admission = bench_admission(quick=quick)
    serve = stream + multitenant + replay + replan + admission

    by_name = {r["name"]: r for r in serve}
    summary = {}
    day, host = stream[0], stream[1]
    summary["serve_stream_events_per_sec"] = day["events_per_sec"]
    summary["serve_stream_p99_latency_s"] = day["p99_latency_s"]
    summary["serve_stream_mean_slowdown"] = day["mean_slowdown"]
    summary["serve_stream_warm_fraction"] = (
        day["warm_replans"] / max(1, day["replans"]))
    summary["serve_stream_device_vs_host_x"] = (
        host["us_per_call"] / day["us_per_call"])
    summary["serve_stream_parity_max_diff"] = (
        day["parity_max_completion_diff"])
    mt = {r["tenants"]: r for r in multitenant}
    if 1 in mt and 8 in mt:
        summary["serve_multitenant_T8_vs_T1_x"] = (
            mt[8]["events_per_sec"] / mt[1]["events_per_sec"])
    summary["serve_trace_replay_events_per_sec"] = (
        replay[0]["events_per_sec"])
    for M in (8, 16):
        wr = by_name.get(f"serve_warm_replan_M{M}")
        cr = by_name.get(f"serve_cold_replan_M{M}")
        if wr and cr:
            summary[f"serve_warm_vs_cold_replan_M{M}_x"] = (
                cr["us_per_call"] / wr["us_per_call"])
    wr = by_name.get("serve_warm_replan_shared_M16")
    cr = by_name.get("serve_cold_replan_shared_M16")
    if wr and cr:
        summary["serve_warm_vs_cold_replan_shared_x"] = (
            cr["us_per_call"] / wr["us_per_call"])
    # the acceptance headline: incremental replanning must be at least
    # 2x cheaper than planning from scratch on the same live state
    summary["serve_warm_vs_cold_replan_x"] = max(
        v for k, v in summary.items()
        if k.startswith("serve_warm_vs_cold_replan_M"))
    return {
        "calibration": bench_calibration(),
        "serve": serve,
        "summary": summary,
        "config": {"B": B, "quick": quick,
                   "x64": jax.config.jax_enable_x64},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--multitenant-worker", type=int, default=None,
                    help="internal: emit serve_multitenant rows for this "
                         "process's forced device count as JSON on stdout")
    args = ap.parse_args()
    if args.multitenant_worker is not None:
        print(json.dumps(bench_multitenant_worker(args.multitenant_worker,
                                                  args.quick)))
        return
    report = collect(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in report["serve"]:
        extra = ""
        if "events_per_sec" in r:
            extra = f"  {r['events_per_sec']:.0f} events/s"
        if "p99_latency_s" in r:
            extra += (f"  p99 {r['p99_latency_s']:.2f}s"
                      f"  warm {r['warm_replans']}/{r['replans']}")
        print(f"{r['name']:40s} {r['us_per_call']:12.1f} µs/call{extra}")
    for k, v in report["summary"].items():
        print(f"  {k:42s} {v:.3f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
