"""Scheduler-core micro-benchmarks: µs/call for GWF and SmartFill.

These are the latencies a cluster controller pays per decision — the
numbers behind the "low complexity" claim of the paper's abstract.  The
headline comparison is single-instance µs/call (warm, jitted,
device-resident) versus batched planning throughput in instances/sec:
``smartfill_batched`` solves hundreds of padded (x, w, B) instances in
one vmap'd call, which is how a multi-tenant controller amortizes the
solver.

Run directly to write ``BENCH_core.json``:
    PYTHONPATH=src python -m benchmarks.perf_core [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (log_speedup, plan_classes, power,
                        sample_class_workloads, sample_workloads,
                        shifted_power, simulate_ensemble,
                        simulate_fluid_classes, simulate_policy_device,
                        smartfill, smartfill_batched, smartfill_hetero)
from repro.core.gwf import (solve_cap, solve_cap_regular_reference)
from repro.kernels.gwf_waterfill.ops import (generic_waterfill_op,
                                             gwf_waterfill_ref)
from repro.sched.policies import (ClassSmartFillPolicy, EquiPolicy,
                                  HeSRPTPolicy, HeteroSmartFillPolicy,
                                  SmartFillPolicy,
                                  WeightedMarginalRatePolicy)

B = 10.0


def _time(fn, *args, reps=100, warmup=3):
    """Best-of-reps warm latency in µs.

    The minimum is the standard robust statistic for micro-benchmarks:
    it estimates the cost of the work itself, while means absorb
    scheduler noise from shared runners — which is exactly what the
    >30% regression gate (benchmarks/check_regression.py) must not
    trip on.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # µs


def bench_calibration():
    """Fixed-work machine-speed probe for the regression gate.

    A jitted dense matmul chain touches none of the scheduler code, so
    its time moves only with the runner's speed — the valid
    ``--calibrate`` row for ``check_regression.py`` (a row that shares
    the gated hot path would rescale a core regression into every other
    row and hide it).
    """
    x = jnp.ones((384, 384), jnp.float32)
    f = jax.jit(lambda x: (x @ x @ x).sum())
    return [{"name": "calibration_fixed_work", "us_per_call": _time(f, x)}]


def bench_gwf(quick: bool = False):
    """CAP/WFP solver latencies across job counts k.

    ``gwf_closed_form_k*``     — the O(k log k) prefix-sum closed form
                                 (the default ``solve_cap`` path);
    ``gwf_closed_form_ref_k*`` — the legacy O(k²) breakpoint search;
    ``gwf_waterfill_ref_k*``   — the (u, h0) WFP oracle;
    ``gwf_generic_waterfill_k*`` — the fused λ-bisection path behind
                                 ``impl="auto"`` (Pallas on TPU, jnp
                                 reference elsewhere).
    """
    rows = []
    sp = shifted_power(1.0, 4.0, 0.5, B)
    for k in (8, 64, 512, 4096):
        c = jnp.sort(jax.random.uniform(jax.random.PRNGKey(0), (k,),
                                        jnp.float32, 0.01, 1.0))[::-1]
        fn = jax.jit(lambda b, c: solve_cap(sp, b, c))
        us = _time(fn, 5.0, c)
        rows.append({"name": f"gwf_closed_form_k{k}", "us_per_call": us})
        if not (quick and k >= 4096):   # the O(k²) path is ~100× slower
            fn_ref = jax.jit(lambda b, c: solve_cap_regular_reference(sp, b, c))
            us_ref = _time(fn_ref, 5.0, c, reps=5 if k >= 4096 else 50)
            rows.append({"name": f"gwf_closed_form_ref_k{k}",
                         "us_per_call": us_ref})
        fn2 = jax.jit(lambda u, h0, b: gwf_waterfill_ref(u, h0, b))
        us2 = _time(fn2, sp.bottle_width(c).astype(jnp.float32),
                    sp.bottle_bottom(c).astype(jnp.float32), 5.0)
        rows.append({"name": f"gwf_waterfill_ref_k{k}", "us_per_call": us2})
        fn3 = jax.jit(lambda c, b: generic_waterfill_op(
            c, sp.A, sp.w, sp.gamma, b, sigma=sp.sigma))
        us3 = _time(fn3, c[None, :].astype(jnp.float32),
                    jnp.asarray([5.0], jnp.float32))
        rows.append({"name": f"gwf_generic_waterfill_k{k}",
                     "us_per_call": us3})
    return rows


_SPS = {
    "power": power(1.0, 0.5, B),         # closed-form μ* fast path
    "regular": shifted_power(1.0, 4.0, 0.5, B),
    "log": log_speedup(1.0, 1.0, B),
}


def bench_smartfill(ms=(10, 50, 100), reps=15):
    """Warm single-instance latency: one jitted device program per call."""
    rows = []
    for M in ms:
        x = np.arange(M, 0, -1.0)
        w = 1.0 / x
        for name, sp in _SPS.items():
            def run():
                # materializes J host-side, so the call blocks inherently
                return smartfill(sp, x, w, B=B, validate=False)
            out = run()                             # compile + warm
            rows.append({"name": f"smartfill_{name}_M{M}",
                         "family": name, "M": M,
                         "us_per_call": _time(run, reps=reps, warmup=1),
                         "J": out.J})
    return rows


def bench_smartfill_batched(n_instances=256, ms=(16, 32), reps=3):
    """Batched planning throughput: N padded instances per vmap'd call."""
    rows = []
    rng = np.random.default_rng(0)
    for M in ms:
        scale = rng.uniform(0.5, 2.0, (n_instances, 1))
        X = np.tile(np.arange(M, 0, -1.0), (n_instances, 1)) * scale
        W = 1.0 / X
        for name, sp in _SPS.items():
            def run():
                out = smartfill_batched(sp, X, W, B=B)
                jax.block_until_ready(out.J)
                return out
            dt = _time(run, reps=reps, warmup=1) / 1e6
            rows.append({
                "name": f"smartfill_batched_{name}_N{n_instances}_M{M}",
                "family": name, "M": M,
                "us_per_call": dt * 1e6,
                "instances_per_sec": n_instances / dt,
                "us_per_instance": dt / n_instances * 1e6,
            })
    return rows


def bench_simulator(K=256, M=16, reps=3):
    """Scenario-engine throughput: simulated events/sec, single vs ensemble.

    Single = one jitted ``lax.scan`` run of re-planning SmartFill on an
    M-job instance; ensemble = P policies × K random workloads in one
    compiled call (``simulate_ensemble``).  Events counted are executed
    (non-halt) engine events.
    """
    sp = power(1.0, 0.5, B)
    x = np.arange(M, 0, -1.0)
    w = 1.0 / x
    pol_sf = SmartFillPolicy(sp, B=B)

    def run_single():
        return simulate_policy_device(sp, x, w, pol_sf, B=B, trace=False)

    res = run_single()                              # compile + warm
    n_ev = res.n_events
    dt_single = _time(run_single, reps=reps, warmup=1) / 1e6
    rows = [{
        "name": f"sim_single_smartfill_M{M}",
        "us_per_call": dt_single * 1e6,
        "events_per_sec": n_ev / dt_single,
        "events": n_ev,
    }]

    wl = sample_workloads(0, K=K, M=M, B=B, m_range=(max(2, M // 2), M))
    policies = (pol_sf, HeSRPTPolicy(0.5, B), EquiPolicy(B))

    def run_ensemble():
        out = simulate_ensemble(sp, policies, wl.X, wl.W, B=B)
        jax.block_until_ready(out.J)
        return out

    out = run_ensemble()                            # compile + warm
    total_events = int(np.asarray(out.n_events).sum())
    dt_ens = _time(run_ensemble, reps=reps, warmup=1) / 1e6
    rows.append({
        "name": f"sim_ensemble_P{len(policies)}_K{K}_M{M}",
        "us_per_call": dt_ens * 1e6,
        "events_per_sec": total_events / dt_ens,
        "events": total_events,
        "instances_per_sec": len(policies) * K / dt_ens,
    })
    return rows


HETERO_FAMILIES = ("power", "shifted", "log", "neg_power", "saturating")


def bench_hetero(quick: bool = False, reps: int = 15):
    """Heterogeneous (§7) planning + ensemble rows.

    ``hetero_plan_M{32,256}``      — warm single-instance latency of the
        per-job SmartFill solve (fixed heuristic order, mixed families
        incl. the σ=−1 saturating row; every CAP probe is the per-job
        λ-bisection, so these rows gate the §7 hot path the shared
        closed form cannot cover);
    ``hetero_sim_ensemble_*``      — the scenario engine driving the
        pinned-order hetero SmartFill (one-shot plan cached at
        construction, executed by active-count lookup — the §7
        time-consistent policy) and the retired weighted-marginal-rate
        baseline (re-solved every event through the sorted-bracket CAP)
        over a per-job mixed-family ensemble, in simulated events/sec.
        Plan construction is one batched solve outside the timed region.
    """
    rows = []
    for M in (32, 256):
        wl = sample_workloads(7, K=1, M=M, B=B, family=HETERO_FAMILIES,
                              per_job=True)
        sp1 = jax.tree_util.tree_map(lambda l: jnp.asarray(l)[0], wl.sp)
        x, w = wl.X[0], wl.W[0]

        def run():
            return smartfill_hetero(sp1, x, w, B=B, exchange_passes=0)
        out = run()                                 # compile + warm
        # full reps even at M=256: the sorted-bracket rebuild brought it
        # from seconds/call to sub-second, so best-of-15 is affordable
        # and needed (host timer noise here is ±10-20% of the row)
        r = reps
        rows.append({"name": f"hetero_plan_M{M}", "M": M,
                     "us_per_call": _time(run, reps=r, warmup=1),
                     "J": out.J})

    K, M = (32, 12) if quick else (64, 16)
    wl = sample_workloads(8, K=K, M=M, B=B, family=HETERO_FAMILIES,
                          per_job=True, m_range=(max(2, M // 2), M))
    policies = (HeteroSmartFillPolicy.pinned(wl.sp, wl.X, wl.W, B=B,
                                             cache_plan=True),
                WeightedMarginalRatePolicy(wl.sp, B=B))

    def run_ens():
        out = simulate_ensemble(wl.sp, policies, wl.X, wl.W, B=B)
        jax.block_until_ready(out.J)
        return out

    out = run_ens()                                 # compile + warm
    events = int(np.asarray(out.n_events).sum())
    dt = _time(run_ens, reps=3, warmup=1) / 1e6
    rows.append({
        "name": f"hetero_sim_ensemble_P{len(policies)}_K{K}_M{M}",
        "us_per_call": dt * 1e6,
        "events_per_sec": events / dt,
        "events": events,
        "instances_per_sec": len(policies) * K / dt,
    })
    return rows


def bench_classes(quick: bool = False):
    """Class-aggregated (many-jobs limit) planning + fluid engine rows.

    ``class_plan_M1e6_C64`` — one full ``plan_classes`` call on 64
        classes of 15625 jobs each (M = 10⁶): host prep + aggregation
        transform + the §7 solve on 64 aggregate rows + exchange
        passes.  This is the ROADMAP "millions of users" headline —
        per-job planning at this M is off the chart (the per-job bench
        ceiling is M = 256), aggregation makes it a ~64-row solve.
    ``class_fluid_ensemble_*`` — the fluid class engine executing the
        cached one-shot plan over K mixed-family instances, in
        events/sec (each event completes at least one class).
    """
    C = 64
    per = 1_000_000 // C                    # 15625 jobs/class → M = 10⁶
    wb = sample_class_workloads(11, K=1, C=C, count_range=(per, per))
    st = wb.state(0)

    def run_plan():
        return plan_classes(st)

    out = run_plan()                        # compile + warm
    rows = [{
        "name": f"class_plan_M1e6_C{C}", "C": C, "jobs": int(out.counts.sum()),
        "us_per_call": _time(run_plan, reps=3 if quick else 5, warmup=1),
        "J": out.J,
    }]

    K, Cf = (8, 12) if quick else (32, 16)
    wb = sample_class_workloads(12, K=K, C=Cf)
    states = [wb.state(k) for k in range(K)]
    pols = [ClassSmartFillPolicy.from_classes(s, cache_plan=True)
            for s in states]                # plan construction not timed

    def run_fluid():
        total = 0
        for s, p in zip(states, pols):
            total += simulate_fluid_classes(s, p, trace=False).n_events
        return total

    events = run_fluid()                    # compile + warm
    dt = _time(run_fluid, reps=3, warmup=1) / 1e6
    rows.append({
        "name": f"class_fluid_ensemble_K{K}_C{Cf}",
        "us_per_call": dt * 1e6,
        "events_per_sec": events / dt,
        "events": events,
        "instances_per_sec": K / dt,
    })
    return rows


def bench_robust(quick: bool = False):
    """Robustness-layer rows: what the control plane pays to be safe.

    ``robust_sf_ensemble_*``     — plain SmartFill ensemble (the
        baseline the certificate overhead is measured against);
    ``robust_cert_ensemble_*``   — the same ensemble behind the full
        ``DegradingPolicy`` ladder (per-event certificates on every
        rung + the GWF-static and EQUI fallbacks evaluated eagerly) —
        the "certificates are nearly free next to the per-event DP"
        claim, quoted as ``robust_certificate_overhead_x``;
    ``robust_faulted_ensemble_*`` — the fault-aware engine under a
        seeded chaos ensemble (budget preemptions + failures +
        stragglers, one trace per workload), in events/sec;
    ``robust_degraded_ensemble_*`` — a sabotaged primary forcing every
        event onto the GWF-static rung; its J against the healthy
        re-planning run is ``robust_degraded_vs_replan_J_gap_pct`` —
        the scheduling cost of running degraded instead of re-solving.
    """
    from repro.core.workloads import sample_fault_traces
    from repro.robust import DegradingPolicy, SaboteurPolicy
    from repro.sched.policies import GWFStaticPolicy

    K, M = (32, 12) if quick else (64, 16)
    sp = _SPS["regular"]
    wl = sample_workloads(21, K=K, M=M, B=B, m_range=(max(2, M // 2), M))
    rows = []

    def ens(policies, faults=None):
        def run():
            out = simulate_ensemble(sp, policies, wl.X, wl.W, B=B,
                                    faults=faults)
            jax.block_until_ready(out.J)
            return out

        out = run()                             # compile + warm
        dt = _time(run, reps=3, warmup=1) / 1e6
        events = int(np.asarray(out.n_events).sum())
        return out, dt, events

    plain = (SmartFillPolicy(sp, B=B),)
    out_p, dt_p, ev_p = ens(plain)
    rows.append({"name": f"robust_sf_ensemble_K{K}_M{M}",
                 "us_per_call": dt_p * 1e6, "events_per_sec": ev_p / dt_p,
                 "events": ev_p, "instances_per_sec": K / dt_p})

    certified = (DegradingPolicy.ladder(sp, B=B),)
    out_c, dt_c, ev_c = ens(certified)
    rows.append({"name": f"robust_cert_ensemble_K{K}_M{M}",
                 "us_per_call": dt_c * 1e6, "events_per_sec": ev_c / dt_c,
                 "events": ev_c, "instances_per_sec": K / dt_c})

    traces = sample_fault_traces(22, K, M, B=B, horizon=6.0,
                                 preempt_rate=0.5, fail_rate=0.3,
                                 straggle_rate=0.3)
    out_f, dt_f, ev_f = ens(plain, faults=traces)
    rows.append({"name": f"robust_faulted_ensemble_K{K}_M{M}",
                 "us_per_call": dt_f * 1e6, "events_per_sec": ev_f / dt_f,
                 "events": ev_f, "instances_per_sec": K / dt_f})

    degraded = (DegradingPolicy(rungs=(
        SaboteurPolicy(SmartFillPolicy(sp, B=B), mode="nan"),
        GWFStaticPolicy(sp, B=B),
        EquiPolicy(B))),)
    out_d, dt_d, ev_d = ens(degraded)
    J_p = np.asarray(out_p.J)[0]
    J_d = np.asarray(out_d.J)[0]
    ok = np.isfinite(J_p) & np.isfinite(J_d) & (J_p > 0)
    gap_pct = float(np.median((J_d[ok] - J_p[ok]) / J_p[ok]) * 100.0)
    rows.append({"name": f"robust_degraded_ensemble_K{K}_M{M}",
                 "us_per_call": dt_d * 1e6, "events_per_sec": ev_d / dt_d,
                 "events": ev_d, "instances_per_sec": K / dt_d,
                 "J_gap_pct": gap_pct})
    return rows


FLEET_DEVICE_COUNTS = (1, 2, 4, 8)


def bench_fleet_worker(devices: int, base_n: int, quick: bool) -> list:
    """Measure sharded planning/simulation on THIS process's devices.

    Runs inside a subprocess whose XLA_FLAGS forced ``devices`` host
    devices (the flag must be set before jax initializes, hence the
    process boundary).  Weak scaling: the per-device load is fixed at
    ``base_n`` instances, so N = base_n · D and ideal instances/sec
    grows linearly with D.
    """
    from repro.distributed import (fleet_mesh, plan_sharded,
                                   simulate_ensemble_sharded)

    if len(jax.devices()) != devices:
        raise RuntimeError(
            f"fleet worker expected {devices} devices, found "
            f"{len(jax.devices())} — XLA_FLAGS not applied?")
    mesh = fleet_mesh()
    N, M = base_n * devices, 16
    sp = _SPS["regular"]
    wl = sample_workloads(0, K=N, M=M, B=B, m_range=(max(2, M // 2), M))

    def run_plan():
        out = plan_sharded(sp, wl.X, wl.W, B=B, mesh=mesh)
        jax.block_until_ready(out.J)
        return out

    run_plan()                                   # compile + warm
    dt = _time(run_plan, reps=3, warmup=1) / 1e6
    rows = [{
        "name": f"fleet_plan_weak_D{devices}",
        "devices": devices, "instances": N,
        "us_per_call": dt * 1e6,
        "instances_per_sec": N / dt,
        "us_per_instance": dt / N * 1e6,
    }]
    if not quick:
        policies = (HeSRPTPolicy(0.5, B), EquiPolicy(B))

        def run_sim():
            out = simulate_ensemble_sharded(sp, policies, wl.X, wl.W, B=B,
                                            mesh=mesh)
            jax.block_until_ready(out.J)
            return out

        out = run_sim()
        events = int(np.asarray(out.n_events).sum())
        dt = _time(run_sim, reps=3, warmup=1) / 1e6
        rows.append({
            "name": f"fleet_sim_weak_D{devices}",
            "devices": devices, "instances": len(policies) * N,
            "us_per_call": dt * 1e6,
            "instances_per_sec": len(policies) * N / dt,
            "events_per_sec": events / dt,
        })
    return rows


def bench_fleet(quick: bool = False):
    """Weak-scaling rows: sharded instances/sec at 1/2/4/8 host devices.

    Each device count runs in its own subprocess because
    ``--xla_force_host_platform_device_count`` only takes effect before
    jax initializes; workers report rows back as JSON on stdout.
    """
    base_n = 32 if quick else 64
    repo = pathlib.Path(__file__).resolve().parent.parent
    rows = []
    for D in FLEET_DEVICE_COUNTS:
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={D}").strip()
        # the forced device count only applies to the CPU backend — on a
        # GPU/TPU host the worker would otherwise come up with the
        # accelerator's device count and hard-fail its sanity check
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = (str(repo / "src") + os.pathsep
                             + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
        cmd = [sys.executable, "-m", "benchmarks.perf_core",
               "--fleet-worker", str(D), "--fleet-base-n", str(base_n)]
        if quick:
            cmd.append("--quick")
        out = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                             text=True)
        if out.returncode != 0:
            raise RuntimeError(
                f"fleet worker D={D} failed:\n{out.stderr[-2000:]}")
        rows.extend(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def collect(quick: bool = False):
    """All rows + the single-vs-batched amortization summary.

    The amortization factor compares a batched call's per-instance cost
    against a warm single-instance call of the *same* family and M.
    """
    n = 64 if quick else 256
    batched_ms = (16,) if quick else (16, 32)
    # hetero's single-instance latency rows run FIRST: every other
    # section leaves allocator/compile-cache pressure behind that
    # inflates a warm ~200 ms row by 10-15% (measured: 186 ms in a
    # clean process vs 215+ ms after the gwf/batched sections)
    hetero = bench_hetero(quick=quick)
    gwf = bench_gwf(quick=quick)
    single = bench_smartfill(ms=(10, 50) if quick else (10, 50, 100))
    single += bench_smartfill(ms=batched_ms)        # same-M baselines
    batched = bench_smartfill_batched(n_instances=n, ms=batched_ms)
    simulator = bench_simulator(K=64 if quick else 256, M=16)
    classes = bench_classes(quick=quick)
    robust = bench_robust(quick=quick)
    fleet = bench_fleet(quick=quick)
    summary = {}
    for r in batched:
        base = next((s for s in single
                     if s["family"] == r["family"] and s["M"] == r["M"]),
                    None)
        if base is not None:
            summary[r["name"] + "_amortization_x"] = (
                base["us_per_call"] / r["us_per_instance"])
    gwf_by_name = {r["name"]: r["us_per_call"] for r in gwf}
    for k in (8, 64, 512, 4096):
        ref = gwf_by_name.get(f"gwf_closed_form_ref_k{k}")
        new = gwf_by_name.get(f"gwf_closed_form_k{k}")
        if ref and new:
            summary[f"gwf_closed_form_k{k}_speedup_x"] = ref / new
    sim_single = simulator[0]
    sim_ens = simulator[1]
    summary["sim_ensemble_events_per_sec"] = sim_ens["events_per_sec"]
    summary["sim_ensemble_amortization_x"] = (
        sim_ens["events_per_sec"] / sim_single["events_per_sec"])
    het_by_name = {r["name"]: r for r in hetero}
    # §7 overhead: per-job λ-bisection CAP vs the shared closed form at
    # the same M (hetero pays bisection per probe; this ratio is the
    # price of heterogeneity the README quotes)
    base50 = next((r for r in single
                   if r["family"] == "regular" and r["M"] == 50), None)
    h32 = het_by_name.get("hetero_plan_M32")
    if base50 and h32:
        summary["hetero_plan_M32_vs_regular_M50_x"] = (
            h32["us_per_call"] / base50["us_per_call"])
    for r in hetero:
        if "events_per_sec" in r:
            summary["hetero_ensemble_events_per_sec"] = r["events_per_sec"]
    cls_by_name = {r["name"]: r for r in classes}
    plan_1e6 = cls_by_name.get("class_plan_M1e6_C64")
    if plan_1e6:
        summary["class_plan_M1e6_ms"] = plan_1e6["us_per_call"] / 1e3
        # per-job jobs/sec through the aggregate planner — the headline
        # the ROADMAP item asks for
        summary["class_plan_M1e6_jobs_per_sec"] = (
            plan_1e6["jobs"] / (plan_1e6["us_per_call"] / 1e6))
    for r in classes:
        if "events_per_sec" in r:
            summary["class_fluid_events_per_sec"] = r["events_per_sec"]
    rob_plain = next((r for r in robust
                      if r["name"].startswith("robust_sf_ensemble")), None)
    rob_cert = next((r for r in robust
                     if r["name"].startswith("robust_cert_ensemble")), None)
    if rob_plain and rob_cert:
        # the certificate-overhead headline: wrapped / unwrapped wall time
        summary["robust_certificate_overhead_x"] = (
            rob_cert["us_per_call"] / rob_plain["us_per_call"])
    rob_faulted = next((r for r in robust
                        if r["name"].startswith("robust_faulted")), None)
    if rob_faulted:
        summary["robust_faulted_events_per_sec"] = (
            rob_faulted["events_per_sec"])
    rob_deg = next((r for r in robust
                    if r["name"].startswith("robust_degraded")), None)
    if rob_deg:
        summary["robust_degraded_vs_replan_J_gap_pct"] = rob_deg["J_gap_pct"]
    # weak-scaling efficiency: throughput relative to D=1 (1.0 = ideal;
    # on an oversubscribed CPU host the curve flattens at the physical
    # core count — the rows pin the mechanism, not the silicon)
    fleet_by_d = {r["devices"]: r for r in fleet
                  if r["name"].startswith("fleet_plan_")}
    base = fleet_by_d.get(1)
    if base:
        for d, r in sorted(fleet_by_d.items()):
            summary[f"fleet_plan_weak_scaling_D{d}_x"] = (
                r["instances_per_sec"] / base["instances_per_sec"])
    return {
        "calibration": bench_calibration(),
        "gwf": gwf,
        "smartfill_single": single,
        "smartfill_batched": batched,
        "simulator": simulator,
        "hetero": hetero,
        "classes": classes,
        "robust": robust,
        "fleet": fleet,
        "summary": summary,
        "config": {"B": B, "n_instances": n, "x64": jax.config.jax_enable_x64,
                   "fleet_devices": list(FLEET_DEVICE_COUNTS)},
    }


def bench_rows(quick: bool = False):
    """Flat row list for CSV harnesses — same sweep as ``collect()``.

    ``benchmarks/run.py`` prints these so the CSV and BENCH_core.json
    always come from one sweep definition.
    """
    report = collect(quick=quick)
    return (report["gwf"] + report["smartfill_single"]
            + report["smartfill_batched"] + report["simulator"]
            + report["hetero"] + report["classes"] + report["robust"]
            + report["fleet"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_core.json")
    ap.add_argument("--fleet-worker", type=int, default=None,
                    help="internal: measure sharded rows on this many "
                         "forced host devices and print them as JSON")
    ap.add_argument("--fleet-base-n", type=int, default=64)
    args = ap.parse_args()
    if args.fleet_worker is not None:
        print(json.dumps(bench_fleet_worker(args.fleet_worker,
                                            args.fleet_base_n, args.quick)))
        return
    report = collect(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for sec in ("smartfill_single", "smartfill_batched", "simulator",
                "hetero", "classes", "robust", "fleet"):
        for r in report[sec]:
            extra = (f"  {r['instances_per_sec']:.0f} inst/s"
                     if "instances_per_sec" in r else "")
            if "events_per_sec" in r:
                extra += f"  {r['events_per_sec']:.0f} events/s"
            print(f"{r['name']:48s} {r['us_per_call']:12.1f} µs/call{extra}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
