"""Scheduler-core micro-benchmarks: µs/call for GWF and SmartFill.

These are the latencies a cluster controller pays per decision — the
numbers behind the "low complexity" claim of the paper's abstract.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import log_speedup, shifted_power, smartfill
from repro.core.gwf import solve_cap
from repro.kernels.gwf_waterfill.ref import gwf_waterfill_ref

B = 10.0


def _time(fn, *args, reps=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def bench_gwf():
    rows = []
    sp = shifted_power(1.0, 4.0, 0.5, B)
    for k in (8, 64, 512, 4096):
        c = jnp.sort(jax.random.uniform(jax.random.PRNGKey(0), (k,),
                                        jnp.float32, 0.01, 1.0))[::-1]
        fn = jax.jit(lambda b, c: solve_cap(sp, b, c))
        us = _time(fn, 5.0, c)
        rows.append({"name": f"gwf_closed_form_k{k}", "us_per_call": us})
        fn2 = jax.jit(lambda u, h0, b: gwf_waterfill_ref(u, h0, b))
        us2 = _time(fn2, sp.bottle_width(c).astype(jnp.float32),
                    sp.bottle_bottom(c).astype(jnp.float32), 5.0)
        rows.append({"name": f"gwf_waterfill_ref_k{k}", "us_per_call": us2})
    return rows


def bench_smartfill():
    rows = []
    for M in (10, 50, 100):
        x = np.arange(M, 0, -1.0)
        w = 1.0 / x
        for name, sp in (("regular", shifted_power(1.0, 4.0, 0.5, B)),
                         ("log", log_speedup(1.0, 1.0, B))):
            t0 = time.perf_counter()
            smartfill(sp, x, w, B=B)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append({"name": f"smartfill_{name}_M{M}",
                         "us_per_call": dt})
    return rows
