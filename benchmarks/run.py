"""Benchmark harness — one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV (us_per_call doubles as the
objective value J for figure rows; ``derived`` carries the comparison).

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small M sweep for CI")
    ap.add_argument("--skip", default="", help="comma-sep bench names")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    from . import figures, perf_core, cluster_sim, roofline_report

    print("name,us_per_call,derived")
    ms = (10, 40, 100) if args.quick else figures.MS

    for name, fn in figures.ALL.items():
        if name in skip:
            continue
        rows = fn(ms) if name not in ("fig7", "fig9") else fn()
        for r in rows:
            if "M" in r:
                derived = (f"hesrpt_J={r['hesrpt_J']:.4f};"
                           f"gap_pct={r['gap_pct']:.2f}")
                if "gap_openloop_pct" in r:
                    derived += f";gap_openloop_pct={r['gap_openloop_pct']:.2f}"
                print(f"{name}_M{r['M']},{r['smartfill_J']:.6f},{derived}")
            else:
                print(f"{name},{r['a_fit']:.4f},"
                      f"p_fit={r['p_fit']:.4f};paper=({r['paper_a']}"
                      f"|{r['paper_p']})")
        sys.stdout.flush()

    if "perf" not in skip:
        for r in perf_core.bench_rows(quick=args.quick):
            parts = []
            if "instances_per_sec" in r:
                parts.append(f"instances_per_sec={r['instances_per_sec']:.0f}")
            if "events_per_sec" in r:
                parts.append(f"events_per_sec={r['events_per_sec']:.0f}")
            print(f"{r['name']},{r['us_per_call']:.1f},{';'.join(parts)}")
            sys.stdout.flush()

    if "cluster" not in skip:
        for r in cluster_sim.bench_cluster():
            print(f"{r['name']},{r['us_per_call']:.4f},{r['derived']}")
        sys.stdout.flush()

    if "roofline" not in skip:
        rows = roofline_report.load()
        for r in roofline_report.summary_rows(rows):
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
