"""Diff a BENCH_core.json / BENCH_serve.json run against its baseline.

Fails (exit 1) when any matched benchmark row regresses by more than
``--threshold`` (default 30%) on its primary metric — us_per_instance
where present, else us_per_call.  Rows present on only one side are
reported but never fail the check (benchmarks may be added/retired, and
quick mode runs a subset).

CI's slow job runs the quick sweep and then:

    PYTHONPATH=src python -m benchmarks.check_regression \
        --current BENCH_core.json --baseline benchmarks/BENCH_baseline.json

The committed baseline is regenerated with ``--update-baseline`` after a
deliberate performance change:

    PYTHONPATH=src python -m benchmarks.perf_core --quick --out /tmp/b.json
    PYTHONPATH=src python -m benchmarks.check_regression \
        --current /tmp/b.json --update-baseline

The threshold can be loosened for noisy runners via the
``BENCH_REGRESSION_THRESHOLD`` env var (a float, e.g. ``0.8``), and
``--calibrate ROW`` (CI passes ``calibration_fixed_work``) divides out
the runner-speed difference measured on that row before comparing —
without it, a baseline recorded on a faster machine than the runner
would flag *every* row.  The calibration row must NOT share any code
the gate protects — ``calibration_fixed_work`` is a fixed-FLOP matmul
chain touching no scheduler code at all; a row that shares the hot
path would rescale a core regression into every other row and hide it.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import re
import shutil
import sys

_SECTIONS = ("calibration", "gwf", "smartfill_single", "smartfill_batched",
             "simulator", "hetero", "classes", "robust", "fleet", "serve")
# rows whose metric scales with forced host devices / sharded tenants:
# fleet weak-scaling (…_D8) and multi-tenant serve (…_T8) alike are
# bounded by the runner's physical cores past its core count
_DEVICE_ROW = re.compile(r"^(?:fleet_.*_D|serve_multitenant_.*_T)(\d+)$")
_DEFAULT_BASELINE = pathlib.Path(__file__).parent / "BENCH_baseline.json"


def _metric(row: dict):
    """(metric name, value) a row is judged on; lower is better."""
    for key in ("us_per_instance", "us_per_call"):
        if key in row:
            return key, float(row[key])
    return None, None


def load_rows(path: str) -> dict:
    with open(path) as f:
        report = json.load(f)
    rows = {}
    for sec in _SECTIONS:
        for row in report.get(sec, []):
            key, val = _metric(row)
            if key is not None:
                rows[row["name"]] = (key, val)
    return rows


def compare(current: dict, baseline: dict, threshold: float,
            speed_scale: float = 1.0, min_us: float = 0.0,
            min_devices: int | None = None):
    """Returns (regressions, improvements, unmatched) row lists.

    ``speed_scale`` multiplies current values before comparison (< 1 ⇒
    the current machine measured slower on the calibration row, so its
    times are scaled down accordingly).  Rows whose baseline metric is
    under ``min_us`` sit below the timer/dispatch noise floor of shared
    runners and are skipped rather than gated.  Fleet weak-scaling rows
    above ``min_devices`` forced host devices are likewise skipped *and
    said so*: on oversubscribed CI runners the scaling curve flattens
    past ~2 devices at the whim of the machine's physical core count,
    so those rows measure the runner, not the sharding mechanism — but
    hiding them silently would let a real multi-device regression ride
    along, hence the explicit [skip] line per excluded row.
    """
    regressions, improvements, unmatched = [], [], []
    for name, (key, base_val) in sorted(baseline.items()):
        if name not in current:
            unmatched.append(f"baseline-only: {name}")
            continue
        if base_val < min_us:
            unmatched.append(f"sub-noise-floor (<{min_us:g}us): {name}")
            continue
        dev_row = _DEVICE_ROW.match(name)
        if (min_devices is not None and dev_row
                and int(dev_row.group(1)) > min_devices):
            unmatched.append(
                f"above --min-devices={min_devices} (runner-bound "
                f"weak-scaling row, not gated): {name}")
            continue
        cur_key, cur_val = current[name]
        cur_val = cur_val * speed_scale
        ratio = cur_val / base_val if base_val > 0 else float("inf")
        line = (f"{name:44s} {key:>15s}  base {base_val:12.1f}  "
                f"now {cur_val:12.1f}  ({ratio:5.2f}x)")
        if ratio > 1.0 + threshold:
            regressions.append(line)
        elif ratio < 1.0 / (1.0 + threshold):
            improvements.append(line)
    for name in sorted(set(current) - set(baseline)):
        unmatched.append(f"current-only:  {name}")
    return regressions, improvements, unmatched


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default="BENCH_core.json")
    ap.add_argument("--baseline", default=str(_DEFAULT_BASELINE))
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_THRESHOLD", 0.30)))
    ap.add_argument("--calibrate", default=None, metavar="ROW",
                    help="divide out runner-speed drift measured on this "
                         "benchmark row (use calibration_fixed_work)")
    ap.add_argument("--min-us", type=float, default=250.0,
                    help="skip rows whose baseline metric is below this "
                         "(sub-quarter-millisecond timings jitter far "
                         "beyond 30%% on shared runners); 0 gates "
                         "everything")
    ap.add_argument("--min-devices", default=None,
                    help="skip (but report) fleet weak-scaling and "
                         "multi-tenant serve rows above this forced-device/"
                         "tenant count: past the runner's physical cores "
                         "the curve is bounded by the machine, so those "
                         "rows gate the runner, not the code; 'auto' "
                         "resolves to this machine's os.cpu_count(); CI "
                         "passes 2")
    ap.add_argument("--update-baseline", action="store_true",
                    help="copy --current over --baseline and exit")
    args = ap.parse_args(argv)
    if args.min_devices is not None:
        args.min_devices = (os.cpu_count() or 1) \
            if args.min_devices == "auto" else int(args.min_devices)

    if args.update_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated from {args.current} -> {args.baseline}")
        return 0

    if not pathlib.Path(args.baseline).exists():
        print(f"no baseline at {args.baseline}; nothing to check")
        return 0

    current = load_rows(args.current)
    baseline = load_rows(args.baseline)
    speed_scale = 1.0
    if args.calibrate:
        if args.calibrate in current and args.calibrate in baseline:
            cur_cal = current[args.calibrate][1]
            base_cal = baseline[args.calibrate][1]
            if cur_cal > 0 and base_cal > 0:
                speed_scale = base_cal / cur_cal
            print(f"calibrated on {args.calibrate}: runner is "
                  f"{1.0 / speed_scale:.2f}x the baseline machine's time "
                  f"(scale {speed_scale:.3f})")
        else:
            print(f"calibration row {args.calibrate!r} missing on one "
                  "side; comparing uncalibrated")
    regressions, improvements, unmatched = compare(
        current, baseline, args.threshold, speed_scale, args.min_us,
        args.min_devices)

    for line in unmatched:
        print(f"[skip] {line}")
    for line in improvements:
        print(f"[faster] {line}")
    if regressions:
        print(f"\n{len(regressions)} row(s) regressed more than "
              f"{args.threshold:.0%}:")
        for line in regressions:
            print(f"[REGRESSION] {line}")
        return 1
    print(f"\nOK: no row regressed more than {args.threshold:.0%} "
          f"({len(baseline)} baseline rows, {len(current)} current)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
