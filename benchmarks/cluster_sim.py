"""Cluster-scheduling benchmark: SmartFill vs heSRPT on a TPU pod.

Jobs are real (arch × shape) cells with roofline-calibrated speedup
functions from the dry-run (sched/speedup_models.py) — the paper's
technique driving the actual framework.  Because a DP training job's
speedup is Table-1-row-3 *regular* with s'(0) < ∞, SmartFill parks
low-priority jobs (heSRPT cannot) and wins on weighted completion time.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import (fit_power, hesrpt_policy, neg_power,
                        simulate_policy, smartfill)
from repro.sched.cluster import ClusterScheduler, Job
from repro.sched.speedup_models import calibrate_from_dryrun, job_speedup

B_CHIPS = 256.0


def _cluster_speedup():
    """Shared speedup: a mid-size DP job on the production pod.

    Falls back to an analytic roofline if no dry-run JSON is present.
    """
    path = "dryrun_single_pod.json"
    if os.path.exists(path):
        cal = calibrate_from_dryrun(path, B=B_CHIPS)
        key = ("deepseek-7b", "train_4k")
        if key in cal:
            return cal[key]
    return job_speedup(step_flops=6 * 7e9 * 1e6, grad_bytes=2 * 7e9,
                       tokens_per_step=1e6, B=B_CHIPS)


def bench_cluster(M: int = 12):
    sp = _cluster_speedup()
    rng = np.random.default_rng(0)
    sizes = np.sort(rng.uniform(1.0, 20.0, M))[::-1] * 1e9  # tokens of work
    weights = 1.0 / sizes
    jobs = [Job(name=f"job{i}", size=float(sizes[i]),
                weight=float(weights[i])) for i in range(M)]

    cs = ClusterScheduler(sp, B_CHIPS)
    _, J_sf = cs.simulate([Job(**vars(j)) for j in jobs])

    a_fit, p_fit = fit_power(
        lambda t: float(sp.s(np.float64(max(t, 1e-6)))), B_CHIPS)
    he = simulate_policy(sp, sizes, weights, hesrpt_policy(p_fit, B_CHIPS),
                         B=B_CHIPS)

    _, J_cost = ClusterScheduler(sp, B_CHIPS, realloc_cost_s=30.0,
                                 min_delta=2.0).simulate(
        [Job(**vars(j)) for j in jobs])
    _, J_int = ClusterScheduler(sp, B_CHIPS, integer_chips=True).simulate(
        [Job(**vars(j)) for j in jobs])

    gap = 100 * (he.J - J_sf) / he.J
    return [
        {"name": "cluster_smartfill_J", "us_per_call": J_sf,
         "derived": f"M={M};B={B_CHIPS}"},
        {"name": "cluster_hesrpt_J", "us_per_call": he.J,
         "derived": f"fit=a{a_fit:.3f}p{p_fit:.3f};smartfill_wins_pct={gap:.2f}"},
        {"name": "cluster_smartfill_realloc30s_J", "us_per_call": J_cost,
         "derived": "realloc_cost=30s;min_delta=2chips"},
        {"name": "cluster_smartfill_integer_chips_J", "us_per_call": J_int,
         "derived": f"integrality_overhead_pct="
                    f"{100*(J_int-J_sf)/J_sf:.3f}"},
    ]
