"""Cluster-scheduling benchmark: SmartFill vs heSRPT on a TPU pod.

Jobs are real (arch × shape) cells with roofline-calibrated speedup
functions from the dry-run (sched/speedup_models.py) — the paper's
technique driving the actual framework.  Because a DP training job's
speedup is Table-1-row-3 *regular* with s'(0) < ∞, SmartFill parks
low-priority jobs (heSRPT cannot) and wins on weighted completion time.
"""
from __future__ import annotations

import os

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import fit_power, simulate_ensemble, simulate_policy
from repro.sched.cluster import ClusterScheduler, Job
from repro.sched.policies import EquiPolicy, HeSRPTPolicy, SmartFillPolicy
from repro.sched.speedup_models import calibrate_from_dryrun, job_speedup

B_CHIPS = 256.0


def _cluster_speedup():
    """Shared speedup: a mid-size DP job on the production pod.

    Falls back to an analytic roofline if no dry-run JSON is present.
    """
    path = "dryrun_single_pod.json"
    if os.path.exists(path):
        cal = calibrate_from_dryrun(path, B=B_CHIPS)
        key = ("deepseek-7b", "train_4k")
        if key in cal:
            return cal[key]
    return job_speedup(step_flops=6 * 7e9 * 1e6, grad_bytes=2 * 7e9,
                       tokens_per_step=1e6, B=B_CHIPS)


def bench_cluster(M: int = 12):
    sp = _cluster_speedup()
    rng = np.random.default_rng(0)
    sizes = np.sort(rng.uniform(1.0, 20.0, M))[::-1] * 1e9  # tokens of work
    weights = 1.0 / sizes
    jobs = [Job(name=f"job{i}", size=float(sizes[i]),
                weight=float(weights[i])) for i in range(M)]

    # exact (cost-free) run goes through the device scenario engine
    cs = ClusterScheduler(sp, B_CHIPS)
    _, J_sf = cs.simulate([Job(**vars(j)) for j in jobs])

    a_fit, p_fit = fit_power(
        lambda t: float(sp.s(np.float64(max(t, 1e-6)))), B_CHIPS)
    he = simulate_policy(sp, sizes, weights,
                         HeSRPTPolicy(p=p_fit, B=B_CHIPS), B=B_CHIPS)

    # host event loop still charges the real-world costs
    _, J_cost = ClusterScheduler(sp, B_CHIPS, realloc_cost_s=30.0,
                                 min_delta=2.0).simulate(
        [Job(**vars(j)) for j in jobs])
    _, J_int = ClusterScheduler(sp, B_CHIPS, integer_chips=True).simulate(
        [Job(**vars(j)) for j in jobs])

    # policy face-off over a random fleet ensemble — one compiled call.
    # Per-job (not per-fleet) scaling: slowdown-weighted J is invariant
    # under a common scale factor, so per-fleet scaling would collapse
    # the ensemble to 64 copies of one instance.
    K = 64
    X = np.sort(np.tile(sizes, (K, 1)) * rng.uniform(0.5, 2.0, (K, M)),
                axis=1)[:, ::-1].copy()
    W = 1.0 / X
    ens = simulate_ensemble(
        sp, (SmartFillPolicy(sp, B=B_CHIPS),
             HeSRPTPolicy(p=p_fit, B=B_CHIPS), EquiPolicy(B_CHIPS)),
        X, W, B=B_CHIPS)
    Jm = np.asarray(ens.J).mean(axis=1)

    gap = 100 * (he.J - J_sf) / he.J
    return [
        {"name": "cluster_smartfill_J", "us_per_call": J_sf,
         "derived": f"M={M};B={B_CHIPS}"},
        {"name": "cluster_hesrpt_J", "us_per_call": he.J,
         "derived": f"fit=a{a_fit:.3f}p{p_fit:.3f};smartfill_wins_pct={gap:.2f}"},
        {"name": "cluster_smartfill_realloc30s_J", "us_per_call": J_cost,
         "derived": "realloc_cost=30s;min_delta=2chips"},
        {"name": "cluster_smartfill_integer_chips_J", "us_per_call": J_int,
         "derived": f"integrality_overhead_pct="
                    f"{100*(J_int-J_sf)/J_sf:.3f}"},
        {"name": f"cluster_ensemble_K{K}_meanJ", "us_per_call": float(Jm[0]),
         "derived": (f"hesrpt_meanJ={Jm[1]:.4e};equi_meanJ={Jm[2]:.4e};"
                     f"policies={'|'.join(ens.policy_names)}")},
    ]
